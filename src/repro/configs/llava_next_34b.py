"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Anyres tiling is STUBBED per the brief: ``input_specs()`` provides precomputed
patch embeddings (B, 2880, d_model) = 4 tiles + 1 base image x 576 patches,
spliced over the prompt's image-token prefix.  The LM backbone is exact.
"""
from ..models.config import ModelConfig

N_PATCH_TOKENS = 2880  # (4 anyres tiles + 1 base) * 576 CLIP patches


def full() -> ModelConfig:
    return ModelConfig(
        name="llava_next_34b",
        n_layers=60, d_model=7168, vocab=64000,
        n_heads=56, n_kv_heads=8, head_dim=128, d_ff=20480,
        act="swiglu",
        frontend="vision_stub", frontend_tokens=N_PATCH_TOKENS,
        frontend_dim=7168, tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava_smoke",
        n_layers=2, d_model=64, vocab=128,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        act="swiglu",
        frontend="vision_stub", frontend_tokens=8, frontend_dim=64,
        tie_embeddings=False, remat=False,
    )
