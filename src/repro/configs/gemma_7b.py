"""gemma-7b [dense]: 28L d_model=3072 16H MHA head_dim=256 d_ff=24576
vocab=256000, GeGLU."""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma_7b",
        n_layers=28, d_model=3072, vocab=256000,
        n_heads=16, n_kv_heads=16, head_dim=256, d_ff=24576,
        act="geglu", tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma_smoke",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=128,
        act="geglu", tie_embeddings=True, remat=False,
    )
