"""zamba2-7b [hybrid]: 81L d_model=3584, Mamba2 backbone (ssm_state=64) with a
single SHARED attention block (32H MHA, d_ff=14336 MLP) applied every 6
layers, vocab=32000.

The shared block's weights are one set reused at 13 depths; each application
keeps its own KV cache row (weights shared, activations not).
"""
from ..models.config import ModelConfig, SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2_7b",
        n_layers=81, d_model=3584, vocab=32000,
        n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14336,
        act="swiglu", block_pattern="zamba_hybrid", hybrid_attn_every=6,
        ssm=SSMConfig(state_dim=64, head_dim=64, expansion=2, conv_width=4),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2_smoke",
        n_layers=5, d_model=64, vocab=128,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        act="swiglu", block_pattern="zamba_hybrid", hybrid_attn_every=2,
        ssm=SSMConfig(state_dim=16, head_dim=16, expansion=2, conv_width=4),
        tie_embeddings=True, remat=False, ssd_chunk=8,
    )
