"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.

The brief's header states 40e top-8 (the bracketed HF card is a 32e model);
we implement the stated 40e top-8 — see DESIGN.md §Arch-applicability.
"""
from ..models.config import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite_moe_3b_a800m",
        n_layers=32, d_model=1536, vocab=49155,
        n_heads=24, n_kv_heads=8, head_dim=64, d_ff=512,
        act="swiglu", moe=MoEConfig(n_experts=40, top_k=8),
        tie_embeddings=True, moe_group_size=4096,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite_moe_smoke",
        n_layers=2, d_model=64, vocab=128,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64,
        act="swiglu", moe=MoEConfig(n_experts=4, top_k=2),
        tie_embeddings=True, remat=False,
    )
