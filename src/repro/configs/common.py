"""Architecture registry + the assigned input-shape grid.

Every assigned architecture has a ``full()`` (exact public config — exercised
only via the ``.lower().compile()`` dry-run) and a ``smoke()`` (reduced same-
family config for CPU tests).  ``for_mesh`` applies TP head/vocab/expert
padding for a given model-axis size (padded slots are zero-masked at init, so
the padded model computes exactly the true architecture).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional

from ..models.config import ModelConfig, MoEConfig, SSMConfig

TP = 16  # production model-axis size (both meshes)

ARCH_IDS: List[str] = [
    "granite_moe_3b_a800m",
    "grok_1_314b",
    "whisper_base",
    "llava_next_34b",
    "zamba2_7b",
    "gemma_7b",
    "qwen2_7b",
    "starcoder2_3b",
    "glm4_9b",
    "mamba2_130m",
]

# ---------------------------------------------------------------------------
# Input-shape grid (the 4 assigned shapes; skips recorded per-arch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs whose attention is quadratic-only: long_500k is skipped (per brief)
FULL_ATTENTION_ARCHS = {
    "granite_moe_3b_a800m", "grok_1_314b", "whisper_base", "llava_next_34b",
    "gemma_7b", "qwen2_7b", "starcoder2_3b", "glm4_9b",
}


def shape_applicable(arch_id: str, shape: str) -> Optional[str]:
    """None if the cell runs; else the skip reason (recorded in EXPERIMENTS)."""
    if shape == "long_500k" and arch_id in FULL_ATTENTION_ARCHS:
        return "pure full attention: 512k decode KV is quadratic-history; skipped per brief"
    return None


# ---------------------------------------------------------------------------
# TP padding
# ---------------------------------------------------------------------------

def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def for_mesh(cfg: ModelConfig, tp: int = TP) -> ModelConfig:
    """Pad head/expert counts to TP divisibility (zero-masked at init)."""
    upd = {}
    if cfg.n_heads and cfg.n_heads % tp:
        upd["n_heads_pad"] = _round_up(cfg.n_heads, tp)
    if cfg.moe is not None and cfg.moe.n_experts % tp == 0:
        pass
    elif cfg.moe is not None:
        # pad experts only when the param overhead is modest (<= 1.5x); a
        # 2x pad (e.g. grok 8 -> 16) would double MoE weight memory — those
        # archs use TP-within-expert (d_ff sharding) instead.
        padded = _round_up(cfg.moe.n_experts, tp)
        if padded <= 1.5 * cfg.moe.n_experts:
            upd["moe"] = dataclasses.replace(cfg.moe, n_experts_pad=padded)
    return dataclasses.replace(cfg, **upd) if upd else cfg


def get_config(arch_id: str, tp: int = TP) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return for_mesh(mod.full(), tp)


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.smoke()
