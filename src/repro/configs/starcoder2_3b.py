"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152, RoPE, plain-GELU MLP."""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2_3b",
        n_layers=30, d_model=3072, vocab=49152,
        n_heads=24, n_kv_heads=2, head_dim=128, d_ff=12288,
        act="gelu", qkv_bias=True, rope_theta=1e5,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2_smoke",
        n_layers=2, d_model=64, vocab=128,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        act="gelu", qkv_bias=True, tie_embeddings=True, remat=False,
    )
