"""whisper-base [audio]: enc-dec, 6+6L d_model=512 8H d_ff=2048 vocab=51865.

Modality frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings (B, 1500, d_model) — the conv1d+log-mel stack is
out of scope; the transformer backbone is exact.
"""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper_base",
        n_layers=6, d_model=512, vocab=51865,
        n_heads=8, n_kv_heads=8, head_dim=64, d_ff=2048,
        act="gelu", enc_dec=True, n_encoder_layers=6,
        frontend="audio_stub", frontend_tokens=1500, frontend_dim=512,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper_smoke",
        n_layers=2, d_model=64, vocab=128,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        act="gelu", enc_dec=True, n_encoder_layers=2,
        frontend="audio_stub", frontend_tokens=32, frontend_dim=64,
        tie_embeddings=True, remat=False,
    )
