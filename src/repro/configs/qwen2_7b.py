"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064,
QKV bias."""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2_7b",
        n_layers=28, d_model=3584, vocab=152064,
        n_heads=28, n_kv_heads=4, head_dim=128, d_ff=18944,
        act="swiglu", qkv_bias=True, rope_theta=1e6,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2_smoke",
        n_layers=2, d_model=64, vocab=128,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        act="swiglu", qkv_bias=True, tie_embeddings=False, remat=False,
    )
