"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2, final-logit softcap 30 (per the public grok-1 release).

314B total params: weights are 2D-sharded (data x model, FSDP+TP) — model-axis
TP alone (16-way) would need 39 GB/chip.
"""
from ..models.config import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="grok_1_314b",
        n_layers=64, d_model=6144, vocab=131072,
        n_heads=48, n_kv_heads=8, head_dim=128, d_ff=32768,
        act="gelu", moe=MoEConfig(n_experts=8, top_k=2),
        logit_softcap=30.0, tie_embeddings=True, fsdp_params=True,
        moe_group_size=4096,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="grok_smoke",
        n_layers=2, d_model=64, vocab=128,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        act="gelu", moe=MoEConfig(n_experts=4, top_k=2),
        logit_softcap=30.0, tie_embeddings=True, remat=False,
    )
