"""mamba2-130m [ssm]: 24L d_model=768, attention-free SSD (state-space
duality), ssm_state=128, vocab=50280."""
from ..models.config import ModelConfig, SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2_130m",
        n_layers=24, d_model=768, vocab=50280,
        block_pattern="mamba",
        ssm=SSMConfig(state_dim=128, head_dim=64, expansion=2, conv_width=4),
        tie_embeddings=True, dp_over_model=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2_smoke",
        n_layers=2, d_model=64, vocab=128,
        block_pattern="mamba",
        ssm=SSMConfig(state_dim=16, head_dim=16, expansion=2, conv_width=4),
        tie_embeddings=True, remat=False, ssd_chunk=8,
    )
