"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552,
RoPE."""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="glm4_9b",
        n_layers=40, d_model=4096, vocab=151552,
        n_heads=32, n_kv_heads=2, head_dim=128, d_ff=13696,
        act="swiglu", tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="glm4_smoke",
        n_layers=2, d_model=64, vocab=128,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        act="swiglu", tie_embeddings=False, remat=False,
    )
