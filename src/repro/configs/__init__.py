from .common import (ARCH_IDS, SHAPES, TP, ShapeSpec, for_mesh, get_config,
                     get_smoke_config, shape_applicable)
