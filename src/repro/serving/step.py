"""Serving steps: batched prefill and single-token decode.

``decode_32k`` / ``long_500k`` dry-run cells lower ``serve_step`` — ONE new
token against a KV/SSM cache of ``seq_len`` — per the brief.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import transformer


def build_prefill_step(cfg):
    def prefill(params, batch):
        logits, _ = transformer.forward(
            cfg, params, batch["tokens"],
            frames=batch.get("frames"),
            patch_embeds=batch.get("patch_embeds"))
        return logits[:, -1, :]
    return prefill


def build_serve_step(cfg):
    def serve_step(params, cache, tokens, pos):
        """tokens: (B, 1); pos: () int32 — returns (next_logits, new_cache)."""
        logits, new_cache = transformer.decode_step(cfg, params, cache,
                                                    tokens, pos)
        return logits[:, -1, :], new_cache
    return serve_step


def greedy_decode(cfg, params, cache, prompt_last_token, start_pos, n_steps):
    """Simple greedy loop used by examples/tests (host loop, jit step)."""
    step = jax.jit(build_serve_step(cfg))
    tok = prompt_last_token
    out = []
    pos = start_pos
    for _ in range(n_steps):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        pos = pos + 1
    return jnp.concatenate(out, axis=1), cache
