from .step import build_prefill_step, build_serve_step, greedy_decode
