"""Sharding rules: param-pytree -> PartitionSpec-pytree, by leaf name.

Policy (mesh axes: optional 'pod', 'data', 'model'):

* TP ('model'): attention heads (padded to a multiple of the model-axis size
  by configs), d_ff, expert dim (when divisible), vocab rows, mamba inner dim.
  A dim is sharded ONLY when divisible by the axis size — otherwise it stays
  replicated (the config-level head padding makes the important ones divisible).
* FSDP (cfg.fsdp_params, grok-scale): weight matrices additionally shard their
  d_model dim over 'data' — XLA all-gathers per layer inside the scan
  (weights-stationary ZeRO-3).
* ZeRO-1 (cfg.zero_stage >= 1): optimizer moments additionally shard their
  largest remaining dim over 'data' (reduce-scatter grads / all-gather params
  is then XLA's natural lowering of the update).
* 'pod' is a pure DP axis: params/opt replicated across pods, batch sharded.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(n: int, d: int) -> bool:
    return d > 0 and n % d == 0


def param_spec(cfg: ModelConfig, mesh: Mesh, path: str, shape) -> P:
    """PartitionSpec for one param leaf.  ``path``: dot-joined key path;
    ``shape`` is the *layer-stacked* shape (leading L dim for scanned leaves).
    """
    tp = _axis_size(mesh, "model")
    name = path.split(".")[-1]
    stacked = any(s in path for s in ("layers.", "enc_layers.", "dec_layers."))
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape

    fsdp = "data" if (cfg.fsdp_params and "data" in mesh.axis_names) else None

    def fd(dim):  # fsdp-shard a d_model-sized dim if divisible
        return fsdp if (fsdp and _div(dim, _axis_size(mesh, "data"))) else None

    if name in ("embed", ):
        return P("model" if _div(shape[0], tp) else None, fd(shape[1]))
    if name == "lm_head":
        return P(fd(shape[0]), "model" if _div(shape[1], tp) else None)
    if name == "scale":
        return P(*lead, *(None,) * len(body))

    if name == "wq":
        return P(*lead, fd(body[0]),
                 "model" if _div(body[1], tp) else None, None)
    if name in ("wk", "wv"):
        return P(*lead, fd(body[0]),
                 "model" if _div(body[1], tp) else None, None)
    if name == "wo":
        return P(*lead, "model" if _div(body[0], tp) else None, None,
                 fd(body[2]))
    if name in ("bq", "bk", "bv"):
        return P(*lead, "model" if _div(body[0], tp) else None, None)

    if name in ("w_up", "w_gate", "w_down") and len(body) == 3:  # MoE (e,d,f)/(e,f,d)
        if _div(body[0], tp):                      # expert parallelism
            return P(*lead, "model", fd(body[1]), None)
        ff_axis = 2 if name != "w_down" else 1     # TP within expert
        spec = [None, None, None]
        if _div(body[ff_axis], tp):
            spec[ff_axis] = "model"
        d_axis = 1 if name != "w_down" else 2
        spec[d_axis] = fd(body[d_axis])
        return P(*lead, *spec)
    if name == "router":
        return P(*lead, fd(body[0]), None)
    if name in ("w_up", "w_gate"):                 # dense MLP (d, f)
        return P(*lead, fd(body[0]), "model" if _div(body[1], tp) else None)
    if name == "w_down":                           # dense MLP (f, d)
        return P(*lead, "model" if _div(body[0], tp) else None, fd(body[1]))

    if name in ("w_x", "w_z"):                     # mamba (d, d_in)
        h = cfg.ssm.n_heads(cfg.d_model) if cfg.ssm else 0
        ok = _div(h, tp)
        return P(*lead, fd(body[0]), "model" if ok else None)
    if name == "w_dt":
        h = cfg.ssm.n_heads(cfg.d_model) if cfg.ssm else 0
        return P(*lead, fd(body[0]), "model" if _div(h, tp) else None)
    if name == "w_bc":
        return P(*lead, fd(body[0]), None)
    if name == "conv_w":
        h = cfg.ssm.n_heads(cfg.d_model) if cfg.ssm else 0
        return P(*lead, None, "model" if _div(h, tp) else None)
    if name in ("a_log", "dt_bias", "d_skip"):
        h = cfg.ssm.n_heads(cfg.d_model) if cfg.ssm else 0
        return P(*lead, "model" if _div(h, tp) else None)
    if name == "out_proj":                         # (d_in, d)
        h = cfg.ssm.n_heads(cfg.d_model) if cfg.ssm else 0
        return P(*lead, "model" if _div(h, tp) else None, fd(body[1]))

    return P(*lead, *(None,) * len(body))


def _path_str(path) -> str:
    out = []
    for pp in path:
        if isinstance(pp, jax.tree_util.DictKey):
            out.append(str(pp.key))
        elif isinstance(pp, jax.tree_util.SequenceKey):
            out.append(str(pp.idx))
    return ".".join(out)


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape) -> dict:
    """Spec pytree for the whole param tree (shapes from jax.eval_shape)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(cfg, mesh, _path_str(path), leaf.shape),
        params_shape)


def zero_extend(spec: P, shape, mesh: Mesh) -> P:
    """ZeRO-1: shard the largest unsharded dim of an optimizer-moment leaf
    over 'data' (if divisible).  No-op when 'data' is absent/used already."""
    if "data" not in mesh.axis_names or "data" in jax.tree.leaves(tuple(spec)):
        return spec
    ds = _axis_size(mesh, "data")
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_dim = -1, -1
    for i, (s, d) in enumerate(zip(entries, shape)):
        if s is None and d % ds == 0 and d > best_dim:
            best, best_dim = i, d
    if best >= 0 and best_dim >= ds:
        entries[best] = "data"
    return P(*entries)


def opt_specs(cfg: ModelConfig, mesh: Mesh, params_shape) -> dict:
    """Specs for one AdamW moment tree (same structure as params)."""
    base = param_specs(cfg, mesh, params_shape)
    if cfg.zero_stage < 1:
        return base
    return jax.tree.map(
        lambda spec, leaf: zero_extend(spec, leaf.shape, mesh),
        base, params_shape)


def to_named(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh) -> P:
    """Token batches: batch dim sharded over all DP axes."""
    return P(dp_axes(mesh))


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape,
                shard_seq: bool = False) -> dict:
    """Decode-cache specs.  Layout (L, B, S, KV, HD) / mamba (L, B, H, N, P).

    Batch over DP axes; for the KV cache, kv-heads over 'model' when
    divisible, otherwise the SEQUENCE dim over 'model' (flash-decode style:
    the q.K^T softmax over a sharded seq axis lowers to tiny max/sum stat
    all-reduces and the cache never moves — vs GSPMD's fallback of gathering
    the whole cache per layer; EXPERIMENTS.md §Perf iteration 3).
    ``shard_seq`` (long-context, batch=1): S over ('data','model') — batch
    gives no parallelism, the 512k history is split over the whole pod.
    """
    tp = _axis_size(mesh, "model")
    dp = dp_axes(mesh)

    def spec(path, leaf):
        name = _path_str(path).split(".")[-1]
        if name in ("k", "v", "xk", "xv", "attn_k", "attn_v"):
            kv = leaf.shape[3]
            seq = leaf.shape[2]
            kv_ax = "model" if _div(kv, tp) else None
            if shard_seq:
                seq_axes = ("data",) if kv_ax else ("data", "model")
                if _div(seq, _axis_size(mesh, "data") *
                        (1 if kv_ax else tp)):
                    return P(None, None, seq_axes, kv_ax, None)
                return P(None, None, None, kv_ax, None)
            if kv_ax:
                return P(None, dp, None, kv_ax, None)
            if _div(seq, tp):
                return P(None, dp, "model", None, None)  # seq-parallel cache
            return P(None, dp, None, None, None)  # e.g. whisper xk: S=1500
        if name == "ssm":                      # (L, B, H, N, P)
            h = leaf.shape[2]
            return P(None, None if shard_seq else dp,
                     "model" if _div(h, tp) else None, None, None)
        if name == "conv":                     # (L, B, W, d_in)
            h = cfg.ssm.n_heads(cfg.d_model) if cfg.ssm else 0
            return P(None, None if shard_seq else dp, None,
                     "model" if _div(h, tp) else None)
        return P(*(None,) * leaf.ndim)

    return jax.tree_util.tree_map_with_path(spec, cache_shape)
