"""Mamba2 block built on the SSD chunked scan.

The chunked-jnp implementation below mirrors the Pallas kernel
(repro/kernels/ssd_scan) op-for-op but compiles on any backend — it is the
default for dry-runs and CPU tests; the Pallas kernel is the TPU fast path
(cfg-switched via ``ssd_impl``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def ssd_chunked(x: Array, a: Array, b: Array, c: Array, chunk: int,
                init_state: Optional[Array] = None):
    """Chunked SSD scan in pure jnp.  x: (B, H, T, P), a: (B, H, T) log-decay,
    b/c: (B, H, T, N).  Returns (y, final_state (B, H, N, P))."""
    bsz, h, t, p = x.shape
    n = b.shape[-1]
    if t % chunk != 0:
        raise ValueError(
            f"ssd_chunked: sequence length t={t} must be a multiple of "
            f"chunk={chunk} (pad the time axis before calling)")
    nc = t // chunk

    xs = x.reshape(bsz, h, nc, chunk, p).astype(jnp.float32)
    as_ = a.reshape(bsz, h, nc, chunk).astype(jnp.float32)
    bs = b.reshape(bsz, h, nc, chunk, n).astype(jnp.float32)
    cs_ = c.reshape(bsz, h, nc, chunk, n).astype(jnp.float32)

    rows = jnp.arange(chunk)[:, None]
    cols = jnp.arange(chunk)[None, :]
    l_mask = rows >= cols

    def step(state, inp):
        xc, ac, bc, cc = inp                       # (B,H,Q,*) per chunk
        cum = jnp.cumsum(ac, axis=-1)              # (B,H,Q) inclusive
        li = cum[..., :, None] - cum[..., None, :]
        l_decay = jnp.where(l_mask, jnp.exp(jnp.where(l_mask, li, 0.0)), 0.0)
        cb = jnp.einsum("bhqn,bhsn->bhqs", cc, bc)
        y_intra = jnp.einsum("bhqs,bhsp->bhqp", cb * l_decay, xc)
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
            "bhqn,bhnp->bhqp", cc, state)
        w = jnp.exp(cum[..., -1:] - cum)[..., None] * bc
        new_state = (jnp.exp(cum[..., -1])[..., None, None] * state
                     + jnp.einsum("bhqn,bhqp->bhnp", w, xc))
        return new_state, y_intra + y_inter

    s0 = (jnp.zeros((bsz, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, ys = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(xs, 2, 0), jnp.moveaxis(as_, 2, 0),
         jnp.moveaxis(bs, 2, 0), jnp.moveaxis(cs_, 2, 0)))
    y = jnp.moveaxis(ys, 0, 2).reshape(bsz, h, t, p)
    return y.astype(x.dtype), final


def ssd_decode_step(state: Array, x: Array, a: Array, b: Array, c: Array):
    """One-token recurrence.  state: (B, H, N, P); x: (B, H, P);
    a: (B, H); b/c: (B, H, N).  Returns (y (B, H, P), new_state)."""
    state = (jnp.exp(a)[..., None, None] * state.astype(jnp.float32)
             + jnp.einsum("bhn,bhp->bhnp", b.astype(jnp.float32),
                          x.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhnp->bhp", c.astype(jnp.float32), state)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_mamba(key, cfg) -> dict:
    """Separate projection matrices (w_x / w_z / w_bc / w_dt) rather than one
    fused in_proj: each output axis is then individually TP-shardable without
    the shard boundary cutting across segment boundaries of a concat axis."""
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expansion * d
    h = s.n_heads(d)
    n = s.state_dim
    ks = jax.random.split(key, 5)
    std = d ** -0.5
    return {
        "w_x": jax.random.normal(ks[0], (d, d_in), cfg.pdtype()) * std,
        "w_z": jax.random.normal(ks[1], (d, d_in), cfg.pdtype()) * std,
        "w_bc": jax.random.normal(ks[2], (d, 2 * n), cfg.pdtype()) * std,
        "w_dt": jax.random.normal(ks[3], (d, h), cfg.pdtype()) * std,
        "conv_w": jax.random.normal(ks[4], (s.conv_width, d_in), cfg.pdtype()) * 0.1,
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "out_proj": jax.random.normal(ks[0], (d_in, d), cfg.pdtype()) * d_in ** -0.5,
    }


def mamba_block(p: dict, cfg, x: Array, ssm_state=None, conv_state=None,
                decode: bool = False):
    """x: (B, T, D).  Train/prefill when decode=False (T arbitrary);
    one-token step when decode=True (T == 1, states required).

    Returns (y, (ssm_state, conv_state))."""
    s = cfg.ssm
    b_, t, d = x.shape
    d_in = s.expansion * d
    h = s.n_heads(d)
    n = s.state_dim
    x_in = x @ p["w_x"].astype(x.dtype)
    z = x @ p["w_z"].astype(x.dtype)
    bc = x @ p["w_bc"].astype(x.dtype)
    b_in, c_in = bc[..., :n], bc[..., n:]
    dt = x @ p["w_dt"].astype(x.dtype)

    # causal depthwise conv over time (width W)
    w = p["conv_w"].astype(x.dtype)                    # (W, d_in)
    if decode:
        conv_state = jnp.concatenate([conv_state[:, 1:], x_in], axis=1)
        x_conv = jnp.einsum("bwc,wc->bc", conv_state.astype(x.dtype), w)[:, None]
        new_conv_state = conv_state
    else:
        # causal depthwise conv as W shifted adds (no (B,T,W,C) blow-up)
        pad = jnp.zeros((b_, s.conv_width - 1, d_in), x.dtype)
        xp = jnp.concatenate([pad, x_in], axis=1)      # (B, T+W-1, d_in)
        x_conv = jnp.zeros((b_, t, d_in), x.dtype)
        for wi in range(s.conv_width):
            x_conv = x_conv + w[wi] * jax.lax.dynamic_slice_in_dim(
                xp, wi, t, axis=1)
        new_conv_state = xp[:, -s.conv_width:]         # last W entries
    x_conv = jax.nn.silu(x_conv)

    # heads
    xh = x_conv.reshape(b_, t, h, s.head_dim)
    dt_soft = jax.nn.softplus(dt.astype(jnp.float32)
                              + p["dt_bias"])          # (B, T, H)
    a = -jnp.exp(p["a_log"]) * dt_soft                 # log-decay (B, T, H)
    bmat = (b_in.astype(jnp.float32)[:, :, None, :]
            * dt_soft[..., None])                      # (B, T, H, N) dt-scaled
    cmat = jnp.broadcast_to(c_in.astype(jnp.float32)[:, :, None, :],
                            (b_, t, h, n))

    if decode:
        y, new_ssm = ssd_decode_step(
            ssm_state, xh[:, 0], a[:, 0], bmat[:, 0], cmat[:, 0])
        y = y[:, None]                                 # (B, 1, H, P)
    else:
        xt = jnp.moveaxis(xh, 1, 2)                    # (B, H, T, P)
        at = jnp.moveaxis(a, 1, 2)                     # (B, H, T)
        bt = jnp.moveaxis(bmat, 1, 2)
        ct = jnp.moveaxis(cmat, 1, 2)
        if getattr(cfg, "ssd_impl", "chunked") == "pallas":
            from ..kernels.ssd_scan import ssd_scan
            yt = ssd_scan(xt, at, bt, ct, chunk=cfg.ssd_chunk)
            new_ssm = None
        else:
            chunk = min(cfg.ssd_chunk, t) if t % min(cfg.ssd_chunk, t) == 0 \
                else t
            yt, new_ssm = ssd_chunked(xt, at, bt, ct, chunk)
        y = jnp.moveaxis(yt, 1, 2)                     # (B, T, H, P)

    y = y + xh * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(b_, t, d_in)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, (new_ssm, new_conv_state)
