"""Unified model configuration covering all assigned architecture families:
dense / MoE / SSM (Mamba2) / hybrid (Zamba2) / enc-dec (Whisper) / VLM (LLaVA).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    n_experts_pad: int = 0        # pad expert dim for EP divisibility; padded
                                  # experts are router-masked (never routed to)

    @property
    def experts_pad(self) -> int:
        return self.n_experts_pad or self.n_experts


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64        # N
    head_dim: int = 64         # P
    expansion: int = 2         # d_inner = expansion * d_model
    conv_width: int = 4

    def n_heads(self, d_model: int) -> int:
        return (self.expansion * d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    # attention stack (None for attention-free archs)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    act: str = "swiglu"              # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # family switches
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    block_pattern: str = "attn"      # attn | mamba | zamba_hybrid
    hybrid_attn_every: int = 6       # zamba: shared attn block cadence
    enc_dec: bool = False            # whisper
    n_encoder_layers: int = 0
    frontend: str = "none"           # none | audio_stub | vision_stub
    frontend_tokens: int = 0         # stub sequence length contributed
    frontend_dim: int = 0            # stub embedding input dim
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0       # grok/gemma-style final-logit softcap
    # TP padding (set by configs/common.for_mesh): padded head counts make
    # head-sharding divisible by the model-axis size; padded slots are
    # zero-masked at init so outputs are exactly those of the true arch.
    n_heads_pad: int = 0             # 0 -> use n_heads
    n_kv_pad: int = 0                # 0 -> use n_kv_heads
    vocab_pad_to: int = 256          # embedding rows rounded up to this
    zero_stage: int = 1              # 0: replicate opt state; 1: shard over data
    fsdp_params: bool = False        # grok-scale: 2D (data, model) weight shard
    fsdp_gather_weights: bool = True # explicit per-use weight gather (ZeRO-3):
                                     # without it GSPMD all-gathers activations
                                     # (orders of magnitude larger) instead
    tp_size: int = 16                # model-axis size the config was padded for
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # implementation switches
    attn_impl: str = "chunked"       # dense | chunked | pallas
    attn_chunk: int = 1024
    moe_group_size: int = 0          # tokens per dispatch group (0 = all):
                                     # dense dispatch einsums cost O(T*E*C*d)
                                     # = O(T^2) — grouping caps it at
                                     # O(T*S*k*d) (Switch-style group capacity)
    dp_over_model: bool = False      # TP-less archs (mamba2-130m): shard the
                                     # batch over 'model' too — otherwise all
                                     # 16 model-axis devices compute identical
                                     # work (15/16 of the pod wasted)
    ssd_chunk: int = 256
    remat: bool = True
    scan_layers: bool = True

    # -- derived ---------------------------------------------------------
    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def heads_pad(self) -> int:
        return self.n_heads_pad or self.n_heads

    @property
    def kv_pad(self) -> int:
        return self.n_kv_pad or self.n_kv_heads

    @property
    def vocab_pad(self) -> int:
        t = self.vocab_pad_to
        return ((self.vocab + t - 1) // t) * t

    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (excludes tiny norm scales' impact)."""
        c = self
        emb = c.vocab * c.d_model
        out = 0 if c.tie_embeddings else c.vocab * c.d_model
        if c.block_pattern == "attn":
            body = (self._attn_params() + self._mlp_params()) * c.n_layers
        elif c.block_pattern == "mamba":
            body = self._mamba_params() * c.n_layers
        else:  # zamba_hybrid: every layer is mamba + ONE shared attn block
            body = self._mamba_params() * c.n_layers + (
                self._attn_params() + self._mlp_params())
        if c.enc_dec:
            enc = (self._attn_params() + self._mlp_params()) * c.n_encoder_layers
            dec_cross = c.n_layers * self._attn_params()
            body += enc + dec_cross
        return emb + out + body

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        c = self
        full_mlp = self._mlp_params()
        active_mlp = full_mlp * c.moe.top_k // c.moe.n_experts
        body_delta = (full_mlp - active_mlp) * c.n_layers
        return self.param_count() - body_delta

    def _attn_params(self) -> int:
        c = self
        q = c.d_model * c.n_heads * c.head_dim
        kv = 2 * c.d_model * c.n_kv_heads * c.head_dim
        o = c.n_heads * c.head_dim * c.d_model
        bias = (c.n_heads + 2 * c.n_kv_heads) * c.head_dim if c.qkv_bias else 0
        return q + kv + o + bias

    def _mlp_params(self) -> int:
        c = self
        gates = 3 if c.act in ("swiglu", "geglu") else 2
        one_expert = gates * c.d_model * c.d_ff
        if c.moe is not None:
            return one_expert * c.moe.n_experts + c.d_model * c.moe.n_experts
        return one_expert

    def _mamba_params(self) -> int:
        c = self
        s = c.ssm
        d_in = s.expansion * c.d_model
        h = s.n_heads(c.d_model)
        # in_proj produces [x, z, B, C, dt]: d_in + d_in + N + N + h
        in_proj = c.d_model * (2 * d_in + 2 * s.state_dim + h)
        conv = s.conv_width * d_in
        out_proj = d_in * c.d_model
        return in_proj + conv + out_proj + 2 * h  # + A, D per head
