"""Neural building blocks (pure functional: init_* returns param pytrees,
apply functions take them explicitly).  All matmul-bearing layers carry
logical sharding hints through ``sharding.py`` spec trees.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def fsdp_full(cfg, p: dict, name: str) -> Array:
    """Explicit ZeRO-3 weight gather (FSDP archs only, e.g. grok-314B).

    Weights enter the step 2D-sharded (d_model over 'data' x TP over
    'model').  Left to itself, GSPMD resolves the d_model contraction by
    all-gathering the *activations* over 'data' (32 GiB f32/layer at grok
    train_4k) and all-reducing partial sums — ~20x the traffic of gathering
    the *weight* shard (3.2 GiB bf16/layer).  Constraining the weight to its
    model-only spec at point-of-use forces the weight gather; its transpose
    in backward is the grad reduce-scatter — textbook ZeRO-3.
    (EXPERIMENTS.md §Perf iteration 2.)
    """
    w = p[name]
    if not getattr(cfg, "fsdp_params", False) \
            or not getattr(cfg, "fsdp_gather_weights", True):
        return w
    from jax.sharding import PartitionSpec as P
    tp = cfg.tp_size
    div = lambda d: d % tp == 0

    if name in ("w_up", "w_gate"):
        spec = (P(None, None, "model" if div(w.shape[-1]) else None)
                if w.ndim >= 3 else P(None, "model" if div(w.shape[-1])
                                      else None))
    elif name == "w_down":
        spec = (P(None, "model" if div(w.shape[-2]) else None, None)
                if w.ndim >= 3 else P("model" if div(w.shape[-2]) else None,
                                      None))
    elif name == "wq":
        spec = P(None, "model" if div(w.shape[-2]) else None, None)
    elif name in ("wk", "wv"):
        spec = P(None, "model" if div(w.shape[-2]) else None, None)
    elif name == "wo":
        spec = P("model" if div(w.shape[-3]) else None, None, None)
    elif name == "embed":
        spec = P("model" if div(w.shape[0]) else None, None)
    elif name == "lm_head":
        spec = P(None, "model" if div(w.shape[-1]) else None)
    else:
        return w
    if w.ndim > len(spec):           # scanned stack: leading L dim
        spec = P(*((None,) * (w.ndim - len(spec)) + tuple(spec)))
    return jax.lax.with_sharding_constraint(w, spec)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}

def rmsnorm(p: dict, x: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))

def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., T, H, D) ; positions: (..., T)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, D/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., T, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA) — three implementations with identical semantics
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> dict:
    """Head-padded attention params (TP divisibility): the padded Q and O
    slots are zeroed, so padded heads contribute exactly 0 to the output and
    the model is numerically the true architecture."""
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.heads_pad, cfg.kv_pad
    h_true, kv_true = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    # Padding layout is PER KV GROUP (GQA maps flat head i -> kv group
    # i // (h/kv)): each group's first g_true slots are real, the rest are
    # zero — so real heads keep their true kv group under padding.
    g_pad = h // max(kv_true, 1)
    g_true = h_true // max(kv_true, 1)
    hmask = ((jnp.arange(h) % max(g_pad, 1)) < g_true)[None, :, None]
    kvmask = (jnp.arange(kv) < kv_true)[None, :, None]
    p = {
        "wq": jax.random.normal(k1, (d, h, hd), cfg.pdtype()) * std * hmask,
        "wk": jax.random.normal(k2, (d, kv, hd), cfg.pdtype()) * std * kvmask,
        "wv": jax.random.normal(k3, (d, kv, hd), cfg.pdtype()) * std * kvmask,
        "wo": jax.random.normal(k4, (h, hd, d), cfg.pdtype())
              * (h_true * hd) ** -0.5 * hmask.reshape(h, 1, 1),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), cfg.pdtype())
        p["bk"] = jnp.zeros((kv, hd), cfg.pdtype())
        p["bv"] = jnp.zeros((kv, hd), cfg.pdtype())
    return p


def _dense_attention(q, k, v, causal: bool, q_offset) -> Array:
    """q: (B, T, H, D), k/v: (B, S, KV, D) -> (B, T, H, D)."""
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    group = h // kv
    qg = q.reshape(b, t, kv, group, d)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        rows = q_offset + jnp.arange(t)[:, None]
        cols = jnp.arange(s)[None, :]
        logits = jnp.where((cols <= rows)[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)


def _chunked_attention(q, k, v, causal: bool, q_offset, chunk: int) -> Array:
    """Flash-style online softmax in pure jnp: lax.scan over KV chunks.

    Peak memory O(B*T*chunk) instead of O(B*T*S) — this is what makes 32k
    prefill lower/compile within per-device HBM, on any backend.
    """
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    group = h // kv
    if s % chunk != 0:
        raise ValueError(
            f"_chunked_attention: KV length s={s} must be a multiple of "
            f"chunk={chunk} (caller pads the KV cache)")
    n_chunks = s // chunk
    qg = (q.astype(jnp.float32) * (d ** -0.5)).reshape(b, t, kv, group, d)
    ks = k.reshape(b, n_chunks, chunk, kv, d).astype(jnp.float32)
    vs = v.reshape(b, n_chunks, chunk, kv, d).astype(jnp.float32)
    rows = q_offset + jnp.arange(t)[:, None]

    def step(carry, inp):
        acc, m_run, l_run = carry
        kc, vc, c_idx = inp
        logits = jnp.einsum("btkgd,bskd->bkgts", qg, kc)
        if causal:
            cols = c_idx * chunk + jnp.arange(chunk)[None, :]
            logits = jnp.where((cols <= rows)[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m_run, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = corr * l_run + p.sum(axis=-1)
        acc = corr[..., None] * acc + jnp.einsum("bkgts,bskd->bkgtd", p, vc)
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, kv, group, t, d), jnp.float32)
    m0 = jnp.full((b, kv, group, t), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kv, group, t), jnp.float32)
    (acc, m_run, l_run), _ = jax.lax.scan(
        step, (acc0, m0, l0),
        (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0),
         jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, t, h, d)
    return out.astype(q.dtype)


def attention(p: dict, cfg, x: Array, positions: Array,
              kv_cache: Optional[tuple] = None, cache_pos=None,
              causal: bool = True, x_kv: Optional[Array] = None,
              precomputed_kv: bool = False):
    """Full attention block.  Returns (out, new_kv_cache).

    kv_cache: (k, v) with shape (B, S_max, KV, D) — decode fills slot
    ``cache_pos`` and attends to the first cache_pos+T entries.
    x_kv: source for K/V (cross-attention); defaults to x.
    precomputed_kv: the cache already holds final K/V (e.g. encoder output
    projections) — attend to it directly, no projection or cache update.
    """
    if precomputed_kv:
        ck, cv = kv_cache
        q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(x.dtype))
        if "bq" in p:
            q = q + p["bq"].astype(x.dtype)
        out = _dense_attention(q, ck.astype(x.dtype), cv.astype(x.dtype),
                               causal=False, q_offset=0)
        y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
        return y, kv_cache

    src = x if x_kv is None else x_kv
    q = jnp.einsum("btd,dhk->bthk", x, fsdp_full(cfg, p, "wq").astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", src, fsdp_full(cfg, p, "wk").astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", src, fsdp_full(cfg, p, "wv").astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if x_kv is None:  # RoPE only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        kv_positions = positions if kv_cache is None else (
            cache_pos + jnp.arange(k.shape[1]))
        k = apply_rope(k, kv_positions, cfg.rope_theta)

    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_pos, axis=1)
        k_eff, v_eff = ck, cv
        q_offset = cache_pos
        new_cache = (ck, cv)
    else:
        k_eff, v_eff = k, v
        q_offset = 0
        new_cache = None

    # decode (t == 1): always the dense path — logits are (B, H, 1, S),
    # tiny, and softmax over a sequence-SHARDED cache lowers to stat
    # all-reduces; the chunked path's scan would re-gather every chunk of
    # the sharded seq dim (§Perf iteration 3).
    if q.shape[1] == 1 and kv_cache is not None:
        out = _dense_attention(q, k_eff, v_eff, causal, q_offset)
    elif cfg.attn_impl == "chunked" and k_eff.shape[1] % cfg.attn_chunk == 0:
        out = _chunked_attention(q, k_eff, v_eff, causal, q_offset,
                                 cfg.attn_chunk)
    elif cfg.attn_impl == "pallas" and kv_cache is None and causal:
        from ..kernels.flash_attention import flash_attention
        qt = jnp.moveaxis(q, 2, 1)
        out = flash_attention(qt, jnp.moveaxis(k_eff, 2, 1),
                              jnp.moveaxis(v_eff, 2, 1),
                              causal=True, interpret=True)
        out = jnp.moveaxis(out, 1, 2)
    else:
        out = _dense_attention(q, k_eff, v_eff, causal, q_offset)

    y = jnp.einsum("bthk,hkd->btd", out, fsdp_full(cfg, p, "wo").astype(x.dtype))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP (gated and plain) + MoE
# ---------------------------------------------------------------------------

def init_mlp(key, cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    gated = cfg.act in ("swiglu", "geglu")
    ks = jax.random.split(key, 3)
    p = {"w_up": jax.random.normal(ks[0], (d, f), cfg.pdtype()) * d ** -0.5,
         "w_down": jax.random.normal(ks[1], (f, d), cfg.pdtype()) * f ** -0.5}
    if gated:
        p["w_gate"] = jax.random.normal(ks[2], (d, f), cfg.pdtype()) * d ** -0.5
    return p


def _act(cfg, g: Array) -> Array:
    if cfg.act == "swiglu":
        return jax.nn.silu(g)
    if cfg.act == "geglu":
        return jax.nn.gelu(g, approximate=True)
    return jax.nn.gelu(g, approximate=True)


def mlp(p: dict, cfg, x: Array) -> Array:
    up = x @ fsdp_full(cfg, p, "w_up").astype(x.dtype)
    if "w_gate" in p:
        up = up * _act(cfg, x @ fsdp_full(cfg, p, "w_gate").astype(x.dtype))
    else:
        up = _act(cfg, up)
    return up @ fsdp_full(cfg, p, "w_down").astype(x.dtype)


def init_moe(key, cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    e = cfg.moe.experts_pad
    gated = cfg.act in ("swiglu", "geglu")
    ks = jax.random.split(key, 4)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * d ** -0.5,
        "w_up": jax.random.normal(ks[1], (e, d, f), cfg.pdtype()) * d ** -0.5,
        "w_down": jax.random.normal(ks[2], (e, f, d), cfg.pdtype()) * f ** -0.5,
    }
    if gated:
        p["w_gate"] = jax.random.normal(ks[3], (e, d, f), cfg.pdtype()) * d ** -0.5
    return p


def moe(p: dict, cfg, x: Array):
    """Top-k token-choice MoE with capacity-bounded dispatch/combine einsums
    (Mesh-TF style — TPU-native: dense MXU contractions, no scatter).

    GROUPED dispatch (Switch-style ``group_size``): the dispatch/combine
    one-hot contractions cost O(T * E * C * d) with C ~ T*k/E — i.e.
    O(T^2 * k * d), quadratic in per-device tokens.  Splitting tokens into
    G independent groups with per-group capacity C/G makes it
    O(T * S * k * d) (S = group size): G-fold cheaper, identical routing
    semantics up to capacity being enforced per group (exactly what
    Switch/GLaM do, for the same reason).

    Returns (out, aux_loss).
    """
    b, t, d = x.shape
    mo = cfg.moe
    e, k = mo.experts_pad, mo.top_k
    tokens = b * t
    s = cfg.moe_group_size or tokens
    s = min(s, tokens)
    while tokens % s:                        # ragged guard: shrink to divisor
        s //= 2
    g = tokens // s
    cap = max(1, int(mo.capacity_factor * s * k / mo.n_experts))

    xf = x.reshape(g, s, d)
    logits = (xf.astype(jnp.float32) @ p["router"])        # (G, S, E_pad)
    if e != mo.n_experts:   # padded experts are never routed to
        logits = jnp.where(jnp.arange(e) < mo.n_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)               # (G, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) in its expert's per-group buffer
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)       # (G, S, k, E)
    flat = onehot.reshape(g, s * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(g, s, k, e)
    pos = (pos_in_expert * onehot).sum(-1)                 # (G, S, k)
    keep = (pos < cap)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                            dtype=jnp.float32)[..., :cap]  # (G, S, k, C)
    e_oh = jax.nn.one_hot(idx, e, dtype=jnp.float32)       # (G, S, k, E)
    disp = jnp.einsum("gske,gskc->gsec", e_oh, pos_oh)     # (G, S, E, C)
    comb = jnp.einsum("gske,gskc,gsk->gsec", e_oh, pos_oh,
                      gate_vals * keep.astype(jnp.float32))

    # dispatch contraction in compute dtype: disp is 0/1 so xe is an exact
    # copy of the (bf16) activations — and the partial-sum all-reduce XLA
    # inserts when it seq-shards this einsum moves half the bytes vs f32
    # (§Perf iteration 6)
    xe = jnp.einsum("gsec,gsd->gecd", disp.astype(x.dtype), xf)
    up = jnp.einsum("gecd,edf->gecf", xe,
                    fsdp_full(cfg, p, "w_up").astype(x.dtype))
    if "w_gate" in p:
        up = up * _act(cfg, jnp.einsum(
            "gecd,edf->gecf", xe, fsdp_full(cfg, p, "w_gate").astype(x.dtype)))
    else:
        up = _act(cfg, up)
    ye = jnp.einsum("gecf,efd->gecd", up,
                    fsdp_full(cfg, p, "w_down").astype(x.dtype))
    y = jnp.einsum("gsec,gecd->gsd", comb, ye.astype(jnp.float32))

    # load-balancing aux loss (Switch-style), over all tokens
    density = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32).mean((0, 1))
    router_prob = probs.mean((0, 1))
    aux = (density * router_prob).sum() * e
    return y.reshape(b, t, d).astype(x.dtype), aux
