from .config import ModelConfig, MoEConfig, SSMConfig
from . import layers, sharding, ssd, transformer
