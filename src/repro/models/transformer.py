"""Full-model assembly for every assigned architecture family.

One functional model with four entry points:

* ``init_params(key, cfg)``            -> param pytree (layers stacked on L for scan)
* ``forward(cfg, params, tokens, ...)`` -> logits           (train / prefill)
* ``init_cache(cfg, batch, seq)``      -> decode cache pytree
* ``decode_step(cfg, params, cache, tokens, pos)`` -> (logits, cache)

Families are selected by ``cfg.block_pattern`` / ``cfg.enc_dec`` / ``cfg.frontend``:

  attn          dense + MoE decoder-only (gemma/qwen2/starcoder2/glm4/granite/grok)
  mamba         pure SSM (mamba2-130m)
  zamba_hybrid  Mamba2 backbone + one *shared* attention block applied every
                ``hybrid_attn_every`` layers (Zamba2)
  enc_dec       Whisper: bidirectional encoder over stubbed frame embeddings,
                causal decoder with cross-attention
  vlm           LLaVA: decoder-only backbone; stubbed patch embeddings are
                spliced over the first image-token positions

Distribution notes: every layer is scanned (params stacked on a leading L
axis) so HLO size is depth-independent; ``cfg.remat`` wraps each layer in
``jax.checkpoint``.  TP head-padding (``n_heads_pad`` etc.) is decided in
``repro.configs`` — padded Q/O rows are zero so outputs are exact.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import (attention, init_attention, init_mlp, init_moe,
                     init_rmsnorm, mlp, moe, rmsnorm)
from .ssd import init_mamba, mamba_block

Array = jax.Array


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_attn_layer(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"ln1": init_rmsnorm(cfg.d_model, cfg.pdtype()),
         "attn": init_attention(k1, cfg),
         "ln2": init_rmsnorm(cfg.d_model, cfg.pdtype())}
    if cfg.moe is not None:
        p["moe"] = init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k2, cfg)
    return p


def _init_mamba_layer(key, cfg) -> dict:
    return {"ln1": init_rmsnorm(cfg.d_model, cfg.pdtype()),
            "mamba": init_mamba(key, cfg)}


def _init_cross_layer(key, cfg) -> dict:
    """Decoder layer with self-attn + cross-attn + mlp (Whisper decoder)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_rmsnorm(cfg.d_model, cfg.pdtype()),
            "attn": init_attention(k1, cfg),
            "ln_x": init_rmsnorm(cfg.d_model, cfg.pdtype()),
            "xattn": init_attention(k2, cfg),
            "ln2": init_rmsnorm(cfg.d_model, cfg.pdtype()),
            "mlp": init_mlp(k3, cfg)}


def _stack_init(init_fn, key, n: int):
    """vmap an init over n keys -> leaves with leading (n, ...) axis."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(key, cfg) -> dict:
    ke, kl, ks, kh = jax.random.split(key, 4)
    emb_std = cfg.d_model ** -0.5
    params = {
        "embed": jax.random.normal(ke, (cfg.vocab_pad, cfg.d_model),
                                   cfg.pdtype()) * emb_std,
        "final_norm": init_rmsnorm(cfg.d_model, cfg.pdtype()),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            kh, (cfg.d_model, cfg.vocab_pad), cfg.pdtype()) * emb_std

    if cfg.enc_dec:
        params["enc_layers"] = _stack_init(
            lambda k: _init_attn_layer(k, cfg), kl, cfg.n_encoder_layers)
        params["enc_norm"] = init_rmsnorm(cfg.d_model, cfg.pdtype())
        params["dec_layers"] = _stack_init(
            lambda k: _init_cross_layer(k, cfg), ks, cfg.n_layers)
    elif cfg.block_pattern == "attn":
        params["layers"] = _stack_init(
            lambda k: _init_attn_layer(k, cfg), kl, cfg.n_layers)
    elif cfg.block_pattern == "mamba":
        params["layers"] = _stack_init(
            lambda k: _init_mamba_layer(k, cfg), kl, cfg.n_layers)
    elif cfg.block_pattern == "zamba_hybrid":
        params["layers"] = _stack_init(
            lambda k: _init_mamba_layer(k, cfg), kl, cfg.n_layers)
        params["shared_attn"] = _init_attn_layer(ks, cfg)
    else:
        raise ValueError(cfg.block_pattern)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _scan_layers(cfg, body, carry, xs, length: int):
    """lax.scan when cfg.scan_layers (HLO size O(1) in depth) else an
    unrolled Python loop (exact per-layer cost accounting for the dry-run's
    roofline extrapolation — XLA cost analysis counts loop bodies once)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a, axis=0), *ys)
    else:
        ys = None
    return carry, ys


def _attn_layer_fwd(cfg, lp, h, positions, causal=True):
    a, _ = attention(lp["attn"], cfg, rmsnorm(lp["ln1"], h, cfg.norm_eps),
                     positions, causal=causal)
    h = h + a
    hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
    if cfg.moe is not None:
        m, aux = moe(lp["moe"], cfg, hn)
    else:
        m, aux = mlp(lp["mlp"], cfg, hn), jnp.float32(0.0)
    return h + m, aux


def _mamba_layer_fwd(cfg, lp, h):
    y, _ = mamba_block(lp["mamba"], cfg,
                       rmsnorm(lp["ln1"], h, cfg.norm_eps))
    return h + y


def _logits(cfg, params, h) -> Array:
    from .layers import fsdp_full
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    w = (fsdp_full(cfg, params, "embed").T if cfg.tie_embeddings
         else fsdp_full(cfg, params, "lm_head"))
    logits = h @ w.astype(h.dtype)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    # mask vocab padding so softmax normalization is exact
    if cfg.vocab_pad != cfg.vocab:
        mask = jnp.arange(cfg.vocab_pad) < cfg.vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


def _decoder_stack(cfg, params, h, positions):
    """Scan the decoder-only stack; returns (h, moe_aux_sum)."""
    if cfg.block_pattern == "attn":
        def body(carry, lp):
            h = carry
            h, aux = _maybe_remat(
                lambda hh: _attn_layer_fwd(cfg, lp, hh, positions), cfg)(h)
            return h, aux
        h, auxs = _scan_layers(cfg, body, h, params["layers"], cfg.n_layers)
        return h, auxs.sum()

    if cfg.block_pattern == "mamba":
        def body(carry, lp):
            h = carry
            h = _maybe_remat(lambda hh: _mamba_layer_fwd(cfg, lp, hh), cfg)(h)
            return h, jnp.float32(0.0)
        h, _ = _scan_layers(cfg, body, h, params["layers"], cfg.n_layers)
        return h, jnp.float32(0.0)

    if cfg.block_pattern == "zamba_hybrid":
        shared = params["shared_attn"]
        every = cfg.hybrid_attn_every

        def body(carry, xs):
            h = carry
            li, lp = xs

            def full(hh):
                hh = _mamba_layer_fwd(cfg, lp, hh)
                use_attn = (li % every) == (every - 1)

                def with_attn(hx):
                    hx2, _ = _attn_layer_fwd(cfg, shared, hx, positions)
                    return hx2
                return jax.lax.cond(use_attn, with_attn, lambda hx: hx, hh)

            h = _maybe_remat(full, cfg)(h)
            return h, jnp.float32(0.0)

        idx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        h, _ = _scan_layers(cfg, body, h, (idx, params["layers"]), cfg.n_layers)
        return h, jnp.float32(0.0)

    raise ValueError(cfg.block_pattern)


def encode(cfg, params, frames: Array) -> Array:
    """Whisper encoder: bidirectional attention over stubbed frame embeddings."""
    h = frames.astype(cfg.dtype())
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)[None, :]

    def body(carry, lp):
        h = carry
        h, _ = _maybe_remat(
            lambda hh: _attn_layer_fwd(cfg, lp, hh, positions, causal=False),
            cfg)(h)
        return h, None
    h, _ = _scan_layers(cfg, body, h, params["enc_layers"], cfg.n_encoder_layers)
    return rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def _cross_decoder_stack(cfg, params, h, positions, enc_out):
    def body(carry, lp):
        h = carry

        def full(hh):
            a, _ = attention(lp["attn"], cfg,
                             rmsnorm(lp["ln1"], hh, cfg.norm_eps),
                             positions, causal=True)
            hh = hh + a
            x, _ = attention(lp["xattn"], cfg,
                             rmsnorm(lp["ln_x"], hh, cfg.norm_eps),
                             positions, causal=False, x_kv=enc_out)
            hh = hh + x
            return hh + mlp(lp["mlp"], cfg,
                            rmsnorm(lp["ln2"], hh, cfg.norm_eps))
        h = _maybe_remat(full, cfg)(h)
        return h, None
    h, _ = _scan_layers(cfg, body, h, params["dec_layers"], cfg.n_layers)
    return h


def forward(cfg, params, tokens: Array,
            frames: Optional[Array] = None,
            patch_embeds: Optional[Array] = None):
    """Causal LM forward.  Returns (logits (B, T, vocab_pad), moe_aux)."""
    from .layers import fsdp_full
    h = jnp.take(fsdp_full(cfg, params, "embed"), tokens,
                 axis=0).astype(cfg.dtype())
    if cfg.frontend == "vision_stub" and patch_embeds is not None:
        # splice precomputed patch embeddings over the image-token prefix
        p = patch_embeds.astype(h.dtype)
        h = jnp.concatenate([p, h[:, p.shape[1]:]], axis=1)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]

    if cfg.enc_dec:
        enc_out = encode(cfg, params, frames)
        h = _cross_decoder_stack(cfg, params, h, positions, enc_out)
        return _logits(cfg, params, h), jnp.float32(0.0)

    h, aux = _decoder_stack(cfg, params, h, positions)
    return _logits(cfg, params, h), aux


# ---------------------------------------------------------------------------
# Decode (single-token serve step with caches)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, seq: int) -> dict:
    """Abstract-friendly cache pytree (all-zeros; dry-run uses eval_shape)."""
    kv, hd = cfg.kv_pad, cfg.head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    cache = {}
    if cfg.enc_dec:
        f = cfg.frontend_tokens
        cache["k"] = jnp.zeros((cfg.n_layers, batch, seq, kv, hd), cdt)
        cache["v"] = jnp.zeros((cfg.n_layers, batch, seq, kv, hd), cdt)
        cache["xk"] = jnp.zeros((cfg.n_layers, batch, f, kv, hd), cdt)
        cache["xv"] = jnp.zeros((cfg.n_layers, batch, f, kv, hd), cdt)
        return cache
    if cfg.block_pattern == "attn":
        cache["k"] = jnp.zeros((cfg.n_layers, batch, seq, kv, hd), cdt)
        cache["v"] = jnp.zeros((cfg.n_layers, batch, seq, kv, hd), cdt)
        return cache
    s = cfg.ssm
    d_in = s.expansion * cfg.d_model
    h = s.n_heads(cfg.d_model)
    cache["ssm"] = jnp.zeros((cfg.n_layers, batch, h, s.state_dim,
                              s.head_dim), jnp.float32)
    cache["conv"] = jnp.zeros((cfg.n_layers, batch, s.conv_width, d_in), cdt)
    if cfg.block_pattern == "zamba_hybrid":
        n_attn = cfg.n_layers // cfg.hybrid_attn_every
        cache["attn_k"] = jnp.zeros((n_attn, batch, seq, kv, hd), cdt)
        cache["attn_v"] = jnp.zeros((n_attn, batch, seq, kv, hd), cdt)
    return cache


def decode_step(cfg, params, cache: dict, tokens: Array, pos: Array):
    """One-token decode.  tokens: (B, 1) int32; pos: scalar int32 (cache fill).

    Returns (logits (B, 1, vocab_pad), new_cache)."""
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype())
    positions = pos + jnp.zeros(tokens.shape, jnp.int32)

    if cfg.enc_dec:
        def body(carry, xs):
            h = carry
            lp, ck, cv, cxk, cxv = xs
            a, (nk, nv) = attention(lp["attn"], cfg,
                                    rmsnorm(lp["ln1"], h, cfg.norm_eps),
                                    positions, kv_cache=(ck, cv),
                                    cache_pos=pos, causal=True)
            h = h + a
            x, _ = attention(lp["xattn"], cfg,
                             rmsnorm(lp["ln_x"], h, cfg.norm_eps),
                             positions, kv_cache=(cxk, cxv), cache_pos=None,
                             causal=False, x_kv=None, precomputed_kv=True)
            h = h + x
            h = h + mlp(lp["mlp"], cfg, rmsnorm(lp["ln2"], h, cfg.norm_eps))
            return h, (nk, nv)
        h, (nks, nvs) = _scan_layers(
            cfg, body, h, (params["dec_layers"], cache["k"], cache["v"],
                           cache["xk"], cache["xv"]), cfg.n_layers)
        new_cache = dict(cache, k=nks, v=nvs)
        return _logits(cfg, params, h), new_cache

    if cfg.block_pattern == "attn":
        def body(carry, xs):
            h = carry
            lp, ck, cv = xs
            h, aux = _attn_decode_layer(cfg, lp, h, positions, ck, cv, pos)
            return h, aux
        h, (nks, nvs) = _scan_layers(
            cfg, body, h, (params["layers"], cache["k"], cache["v"]),
            cfg.n_layers)
        return _logits(cfg, params, h), dict(cache, k=nks, v=nvs)

    if cfg.block_pattern == "mamba":
        def body(carry, xs):
            h = carry
            lp, s_ssm, s_conv = xs
            y, (ns, nc) = mamba_block(lp["mamba"], cfg,
                                      rmsnorm(lp["ln1"], h, cfg.norm_eps),
                                      ssm_state=s_ssm, conv_state=s_conv,
                                      decode=True)
            return h + y, (ns, nc)
        h, (nss, ncs) = _scan_layers(
            cfg, body, h, (params["layers"], cache["ssm"], cache["conv"]),
            cfg.n_layers)
        return _logits(cfg, params, h), dict(cache, ssm=nss, conv=ncs)

    # zamba_hybrid: mamba scan + shared attention every `every` layers.
    every = cfg.hybrid_attn_every
    n_attn = cfg.n_layers // every
    shared = params["shared_attn"]

    def body(carry, xs):
        h = carry
        li, lp, s_ssm, s_conv = xs
        y, (ns, nc) = mamba_block(lp["mamba"], cfg,
                                  rmsnorm(lp["ln1"], h, cfg.norm_eps),
                                  ssm_state=s_ssm, conv_state=s_conv,
                                  decode=True)
        h = h + y
        return h, (ns, nc)

    idx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    # Mamba layers scanned in groups of `every` (shared attention applied
    # after each full group, mirroring forward's (li % every == every-1)
    # cadence); trailing remainder layers run after the last attention.
    # Groups are a Python loop over n_attn (~13) of scans — HLO stays small.
    new_ssm, new_conv = [], []
    new_ak, new_av = [], []
    bounds = [(g * every, (g + 1) * every) for g in range(n_attn)]
    if n_attn * every < cfg.n_layers:                 # remainder, no attn
        bounds.append((n_attn * every, cfg.n_layers))
    if not bounds:                                    # n_layers < every
        bounds = [(0, cfg.n_layers)]
    for g, (lo, hi) in enumerate(bounds):
        sl = slice(lo, hi)
        seg = jax.tree.map(lambda a: a[sl], params["layers"])
        h, (ns, nc) = _scan_layers(
            cfg, body, h, (idx[sl], seg, cache["ssm"][sl], cache["conv"][sl]),
            hi - lo)
        new_ssm.append(ns)
        new_conv.append(nc)
        if g < n_attn:
            a, (nk, nv) = attention(
                shared["attn"], cfg, rmsnorm(shared["ln1"], h, cfg.norm_eps),
                positions, kv_cache=(cache["attn_k"][g], cache["attn_v"][g]),
                cache_pos=pos, causal=True)
            h = h + a
            hn = rmsnorm(shared["ln2"], h, cfg.norm_eps)
            h = h + mlp(shared["mlp"], cfg, hn)
            new_ak.append(nk)
            new_av.append(nv)
    new_cache = dict(cache,
                     ssm=jnp.concatenate(new_ssm, axis=0),
                     conv=jnp.concatenate(new_conv, axis=0))
    if n_attn:
        new_cache["attn_k"] = jnp.stack(new_ak, axis=0)
        new_cache["attn_v"] = jnp.stack(new_av, axis=0)
    return _logits(cfg, params, h), new_cache


def _attn_decode_layer(cfg, lp, h, positions, ck, cv, pos):
    a, (nk, nv) = attention(lp["attn"], cfg,
                            rmsnorm(lp["ln1"], h, cfg.norm_eps),
                            positions, kv_cache=(ck, cv), cache_pos=pos,
                            causal=True)
    h = h + a
    hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
    if cfg.moe is not None:
        m, _ = moe(lp["moe"], cfg, hn)
    else:
        m = mlp(lp["mlp"], cfg, hn)
    return h + m, (nk, nv)
