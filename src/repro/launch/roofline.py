"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(constants per the brief).  The compiled module is the PER-DEVICE (SPMD)
program, so HLO flops/bytes from ``cost_analysis`` and collective payload
shapes parsed from the HLO text are already per-chip quantities:

    compute_s    = flops_per_chip / PEAK_FLOPS
    memory_s     = hbm_bytes_per_chip / HBM_BW
    collective_s = link_bytes_per_chip / ICI_BW

Collective link bytes: sum over collective instructions of the payload
(largest shape in the instruction), x2 for all-reduce (reduce-scatter +
all-gather decomposition of a ring AR moves 2x the shard bytes per chip).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip link bytes by collective kind, parsed from HLO text."""
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        # instruction lines look like: '%x = bf16[...] all-reduce(bf16[...] %y), ...'
        m = re.search(r"=\s+[a-z0-9]+\[[0-9,]*\][^\s]*\s+([a-z\-]+)", ls)
        if not m:
            # tuple-result collectives: '%x = (f32[..], f32[..]) all-reduce(...)'
            m = re.search(r"=\s+\([^)]*\)\s+([a-z\-]+)", ls)
        if not m or m.group(1) not in _COLLECTIVES:
            continue
        kind = m.group(1)
        if f" {kind}(" not in ls and not ls.startswith(f"{kind}("):
            continue
        payload = max((_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(ls)),
                      default=0)
        factor = 2.0 if kind == "all-reduce" else 1.0
        out[kind] += factor * payload
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items()
                       if k in _COLLECTIVES)
    return out


def roofline_terms(flops: float, hbm_bytes: float, link_bytes: float,
                   useful_flops: float = 0.0) -> Dict[str, float]:
    """Three roofline terms + the dominant bound.

    ``roofline_fraction`` = (useful MODEL_FLOPS time) / (roofline bound):
    a perfectly-overlapped step takes max(terms) seconds; the fraction of
    that bound spent on *useful* model flops is the score we hillclimb.
    (Using HLO flops here would score compute-bound-but-wasteful programs
    as 1.0 — redundant compute must not count as useful.)
    """
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": link_bytes / ICI_BW,
    }
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dom
    useful_s = (useful_flops or flops) / PEAK_FLOPS
    terms["roofline_fraction"] = (useful_s / bound) if bound else 0.0
    return terms


def model_flops(cfg, shape_kind: str, n_tokens: int) -> float:
    """MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D forward-only, N = active."""
    n_active = cfg.active_param_count()
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n_active * n_tokens


def sweep_data_axis_terms(n: int, m: int, width: int, r_max: int, max_q: int,
                          data_shards: int = 1,
                          bytes_per: int = 4) -> Dict[str, float]:
    """Analytic per-device roofline inputs for ONE W-wide count sweep under
    d-way data-axis sharding (core/sweeps, ``data_shards``/``RingSpec.
    data_axis``).

    The m-proportional terms — the (m, n·r_max) one-hot read and the
    m x (W·Q·R) contraction — scale by the LOCAL rows m/d, because each
    data-axis device contracts only its shard; counting full m per chip
    (the pre-data-axis model) overstates HBM traffic and flops d-fold.
    The m-independent (W, Q, R) count tables are written once per device
    and, for d > 1, traverse the links once as a psum (all-reduce = 2x the
    payload per chip, matching :func:`collective_bytes`); the BDeu
    reduction that follows is m-free and stays out of the byte model.
    Feed the result to :func:`roofline_terms`.
    """
    d = max(int(data_shards), 1)
    m_local = -(-int(m) // d)                       # ceil: padded shard rows
    onehot_bytes = float(m_local) * n * r_max * bytes_per
    table_bytes = float(width) * max_q * r_max * bytes_per
    return {
        "flops": 2.0 * m_local * width * max_q * r_max,
        "hbm_bytes": onehot_bytes + table_bytes,
        "link_bytes": (2.0 * table_bytes) if d > 1 else 0.0,
        "m_local": float(m_local),
    }
