"""Multi-process async elastic ring launcher.

    PYTHONPATH=src python -m repro.launch.ring_async_run \
        --family link_like --scale 0.02 --m 400 --k 2 --max-rounds 4

The parent samples the benchmark BN, partitions the edges, allocates one
TCP port per ring member, and spawns **one OS process per member** — each
runs :func:`repro.core.ring_async.run_member` (the same unit the threaded
mode and ``cges(engine="async")`` execute) over the localhost data plane,
then writes its result to the shared workdir for the parent to aggregate.

``--jax-distributed`` additionally forms a ``jax.distributed`` cluster
before the members start (coordinator on the parent-chosen port, env
triplet from ``launch.devices.jax_distributed_env``).  On the CPU backend
this is cluster **bootstrap only** — cross-process collectives aren't
implemented there, and the coordination service hard-terminates surviving
processes when a peer dies.  For exactly that reason the kill-one-member
drill (``--die-member I --die-after-round R``: member I hard-exits with
``os._exit(13)`` after posting round R's BN) refuses to combine with
``--jax-distributed``; the survivors re-partition the dead member's edge
subset and finish with k-1 members on our own sockets.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import tempfile
import time

import numpy as np

DIE_EXIT_CODE = 13


# ---------------------------------------------------------------------------
# Worker: one ring member in this process
# ---------------------------------------------------------------------------

def worker_main(spec_path: str) -> int:
    with open(spec_path) as f:
        w = json.load(f)
    # coordinator triplet travels via env (launch.devices.jax_distributed_env)
    # and must be consumed before ANY jax computation — importing repro.core
    # already warms the backend, so the cluster bootstrap happens right here
    # rather than inside run_member
    coord = os.environ.get("REPRO_JAX_COORDINATOR") or None
    if coord is not None:
        import jax

        jax.distributed.initialize(
            coordinator_address=coord,
            num_processes=int(os.environ["REPRO_JAX_NUM_PROCS"]),
            process_id=int(os.environ["REPRO_JAX_PROC_ID"]))

    from ..core.ges import GESConfig
    from ..core.ring_async import AsyncRingSpec, run_member

    z = np.load(w["problem"], allow_pickle=False)
    config = GESConfig(**w["config"])
    spec = AsyncRingSpec(
        member_id=int(w["member_id"]),
        peers=tuple((int(i), str(h), int(p)) for i, h, p in w["peers"]),
        max_rounds=int(w["max_rounds"]),
        speculation=int(w["speculation"]),
        hb_timeout_s=float(w["hb_timeout_s"]),
        wall_limit_s=float(w["wall_limit_s"]),
        jax_coordinator=None,            # cluster already formed above
        die_after_round=(int(w["die_after_round"])
                         if w.get("die_after_round") is not None else None),
        die_hard=True,
    )
    res = run_member(z["data"], z["arities"], z["edge_masks"], spec,
                     config=config, add_limit=w.get("add_limit"))
    np.save(w["out"] + ".adj.npy", np.asarray(res["adj"], dtype=np.int8))
    scalars = {key: val for key, val in res.items()
               if key not in ("adj", "timings")}
    scalars["timings"] = {ph: float(np.sum(v))
                          for ph, v in res["timings"].items()}
    with open(w["out"] + ".json", "w") as f:
        json.dump(scalars, f)
    return 0


# ---------------------------------------------------------------------------
# Parent: spawn k members, aggregate
# ---------------------------------------------------------------------------

def _free_ports(count: int):
    """Reserve `count` distinct free ports (bind, record, close).  The
    children re-bind them; SO_REUSEADDR makes the tiny window benign on a
    CI loopback."""
    socks, ports = [], []
    for _ in range(count):
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        s.bind(("127.0.0.1", 0))
        socks.append(s)
        ports.append(s.getsockname()[1])
    for s in socks:
        s.close()
    return ports


def launch_ring(data, arities, edge_masks, *, config_kwargs, add_limit=None,
                max_rounds=16, speculation=2, hb_timeout_s=3.0,
                wall_limit_s=300.0, jax_distributed=False, die_member=None,
                die_after_round=None, workdir=None, verbose=True) -> dict:
    """Spawn one OS process per ring member and aggregate their results.

    Returns the same aggregate shape as
    ``core.ring_async.run_ring_async_threads`` (graphs/scores/rounds/
    survivors/members/...), plus per-member exit codes."""
    from .devices import jax_distributed_env

    if jax_distributed and die_member is not None:
        raise ValueError(
            "--jax-distributed cannot be combined with a kill drill: the "
            "jax coordination service terminates surviving processes when "
            "a peer dies (see core/ring_async.py docstring)")
    k = int(np.asarray(edge_masks).shape[0])
    workdir = workdir or tempfile.mkdtemp(prefix="ring_async_")
    problem = os.path.join(workdir, "problem.npz")
    np.savez(problem, data=data, arities=arities, edge_masks=edge_masks)

    n_ports = k + (1 if jax_distributed else 0)
    ports = _free_ports(n_ports)
    peers = [[i, "127.0.0.1", ports[i]] for i in range(k)]
    coordinator = f"127.0.0.1:{ports[k]}" if jax_distributed else None

    procs = []
    for i in range(k):
        spec_path = os.path.join(workdir, f"member_{i}.spec.json")
        with open(spec_path, "w") as f:
            json.dump({
                "member_id": i,
                "peers": peers,
                "problem": problem,
                "out": os.path.join(workdir, f"member_{i}"),
                "config": config_kwargs,
                "add_limit": add_limit,
                "max_rounds": max_rounds,
                "speculation": speculation,
                "hb_timeout_s": hb_timeout_s,
                "wall_limit_s": wall_limit_s,
                "die_after_round": (die_after_round if i == die_member
                                    else None),
            }, f)
        env = dict(os.environ)
        env.setdefault("JAX_PLATFORMS", "cpu")
        if coordinator is not None:
            env.update(jax_distributed_env(coordinator, k, i))
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.ring_async_run",
             "--worker", spec_path],
            env=env, cwd=os.getcwd()))

    deadline = time.monotonic() + wall_limit_s + 60.0
    rcs = {}
    for i, p in enumerate(procs):
        try:
            rcs[i] = p.wait(timeout=max(deadline - time.monotonic(), 1.0))
        except subprocess.TimeoutExpired:
            p.kill()
            rcs[i] = -9
    if verbose:
        print(f"[parent] exit codes: {rcs}")

    results = {}
    for i in range(k):
        out = os.path.join(workdir, f"member_{i}")
        if rcs[i] == 0 and os.path.exists(out + ".json"):
            with open(out + ".json") as f:
                results[i] = json.load(f)
            results[i]["adj"] = np.load(out + ".adj.npy")
    survivors = sorted(results)
    if not survivors:
        raise RuntimeError(
            f"async ring launch: no surviving members (exit codes {rcs})")
    rep = results[survivors[0]]
    agg = {
        "graphs": np.stack([results[i]["adj"] for i in survivors]),
        "scores": np.array([results[i]["score"] for i in survivors]),
        "rounds": int(max(results[i]["rounds"] for i in survivors)),
        "live": rep["live"],
        "members": results,
        "survivors": survivors,
        "exit_codes": rcs,
        "timed_out": any(results[i]["timed_out"] for i in survivors),
        "workdir": workdir,
    }
    agg["best_member"] = survivors[int(np.argmax(agg["scores"]))]
    agg["best_adj"] = results[agg["best_member"]]["adj"]
    agg["best_score"] = float(agg["scores"].max())
    return agg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--family", default="link_like",
                    choices=["link_like", "pigs_like", "munin_like"])
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--m", type=int, default=400)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--limit", action="store_true")
    ap.add_argument("--max-rounds", type=int, default=8)
    ap.add_argument("--speculation", type=int, default=2)
    ap.add_argument("--counts-impl", default="fused")
    ap.add_argument("--max-q", type=int, default=256)
    ap.add_argument("--hb-timeout", type=float, default=3.0)
    ap.add_argument("--wall-limit", type=float, default=300.0)
    ap.add_argument("--jax-distributed", action="store_true",
                    help="form a jax.distributed cluster before the members "
                         "start (bootstrap only on CPU; incompatible with "
                         "--die-member)")
    ap.add_argument("--die-member", type=int, default=None)
    ap.add_argument("--die-after-round", type=int, default=None)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.worker is not None:
        raise SystemExit(worker_main(args.worker))

    from ..core.cges import edge_add_limit
    from ..core import partition
    from ..data.bn import benchmark_bn, forward_sample

    t0 = time.time()
    bn = benchmark_bn(args.family, scale=args.scale, seed=args.seed)
    data = forward_sample(bn, args.m, np.random.default_rng(args.seed + 1))
    n = bn.n
    masks = partition.partition_edges(data, bn.arities, args.k)
    lim = edge_add_limit(n, args.k) if args.limit else None
    print(f"{args.family} scale={args.scale}: n={n}, m={args.m}, "
          f"k={args.k} processes")

    agg = launch_ring(
        data, bn.arities, masks,
        config_kwargs={"max_q": args.max_q,
                       "counts_impl": args.counts_impl},
        add_limit=lim, max_rounds=args.max_rounds,
        speculation=args.speculation, hb_timeout_s=args.hb_timeout,
        wall_limit_s=args.wall_limit, jax_distributed=args.jax_distributed,
        die_member=args.die_member, die_after_round=args.die_after_round,
        workdir=args.workdir)

    out = {
        "family": args.family, "n": n, "m": args.m, "k": args.k,
        "jax_distributed": bool(args.jax_distributed),
        "die_member": args.die_member,
        "survivors": agg["survivors"],
        "live": agg["live"],
        "rounds": agg["rounds"],
        "scores": [float(s) for s in agg["scores"]],
        "best_score": agg["best_score"],
        "timed_out": agg["timed_out"],
        "exit_codes": {str(i): rc for i, rc in agg["exit_codes"].items()},
        "deaths": {str(i): agg["members"][i]["deaths"]
                   for i in agg["survivors"]},
        "timings_us": {str(i): agg["members"][i]["timings"]
                       for i in agg["survivors"]},
        "wall_s": round(time.time() - t0, 2),
    }
    print(json.dumps(out, indent=2))
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(out) + "\n")


if __name__ == "__main__":
    main()
