import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count at first init.
"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell on
the production meshes, record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma_7b --shape train_4k --mesh pod1
  PYTHONPATH=src python -m repro.launch.dryrun --all --out benchmarks/results/dryrun.jsonl

Each invocation appends one JSON line per cell (run cells in separate
processes for fault isolation — benchmarks/sweep_dryrun.sh does this).
"""
import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import common as cc
from ..launch import specs as sp
from ..launch.mesh import make_production_mesh
from ..launch.roofline import collective_bytes, model_flops, roofline_terms
from ..models import transformer
from ..training.step import build_train_step
from ..serving.step import build_prefill_step, build_serve_step


def _cost_get(costs, key, default=0.0):
    try:
        v = costs.get(key, default)
        return float(v)
    except Exception:
        return default


def _memory_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(ma, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
    except Exception as e:  # some backends lack memory_analysis
        return {"error": repr(e)}


def _lower_compile(cfg, shape, mesh) -> dict:
    """Lower+compile one program; return its per-chip counts."""
    params_shape = sp.abstract_params(cfg)
    pshard = sp.param_shardings(cfg, mesh, params_shape)

    t0 = time.time()
    if shape.kind == "train":
        opt_shape = sp.abstract_opt(params_shape)
        oshard = sp.opt_shardings(cfg, mesh, params_shape)
        batch = sp.batch_specs(cfg, shape, "train")
        bshard = sp.batch_shard_tree(batch, mesh, cfg)
        step = build_train_step(cfg)
        jitted = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(params_shape, opt_shape, batch)
    elif shape.kind == "prefill":
        batch = sp.batch_specs(cfg, shape, "prefill")
        bshard = sp.batch_shard_tree(batch, mesh, cfg)
        step = build_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(pshard, bshard),
                         out_shardings=None)
        with mesh:
            lowered = jitted.lower(params_shape, batch)
    else:  # decode
        cache_shape = sp.abstract_cache(cfg, shape.global_batch,
                                        shape.seq_len)
        shard_seq = shape.global_batch == 1      # long-context: seq-parallel
        cshard = sp.cache_shardings(cfg, mesh, cache_shape, shard_seq)
        tokens = sp.sds((shape.global_batch, 1), jnp.int32)
        tshard = sp.batch_shard_tree({"tokens": tokens}, mesh, cfg)["tokens"]
        pos = sp.sds((), jnp.int32)
        step = build_serve_step(cfg)
        jitted = jax.jit(step,
                         in_shardings=(pshard, cshard, tshard,
                                       NamedSharding(mesh, P())),
                         out_shardings=(None, cshard),
                         donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(params_shape, cache_shape, tokens, pos)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    costs = compiled.cost_analysis()
    if isinstance(costs, (list, tuple)):
        costs = costs[0]
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": _cost_get(costs, "flops"),
        "hbm_bytes": _cost_get(costs, "bytes accessed"),
        "coll_bytes": coll["total"],
        "collectives": {k: v for k, v in coll.items() if v},
        "memory": _memory_analysis_dict(compiled),
        "seconds_lower": round(t_lower, 2),
        "seconds_compile": round(t_compile, 2),
    }


def _lin(c1: dict, c2: dict, w: float) -> dict:
    """c1 + w * (c2 - c1) on the numeric count fields."""
    keys = ("flops", "hbm_bytes", "coll_bytes")
    return {k: c1[k] + w * (c2[k] - c1[k]) for k in keys}


def _add(c1: dict, c2: dict, w: float = 1.0) -> dict:
    keys = ("flops", "hbm_bytes", "coll_bytes")
    return {k: c1.get(k, 0.0) + w * c2.get(k, 0.0) for k in keys}


def extrapolated_counts(cfg, shape, mesh) -> dict:
    """Exact per-chip counts via unrolled depth-1/2 programs.

    XLA's HLO cost analysis counts a while/scan body ONCE (not x trip count),
    so the scanned full-depth program under-reports flops/bytes/collectives
    by ~L.  We therefore lower unrolled (scan_layers=False) depth-1 and
    depth-2 variants of the SAME program with the SAME shardings: the
    depth-2 minus depth-1 delta is one exact mid-stack layer (fwd+bwd+its
    optimizer slice+its collectives), and

        total = depth1 + (L - 1) * delta

    Whisper (enc+dec) and Zamba (mamba backbone + shared attention block at
    13 depths) extrapolate each component separately.
    """
    import dataclasses as dc
    rep = lambda **kw: dc.replace(cfg, scan_layers=False, **kw)

    if cfg.enc_dec:
        c11 = _lower_compile(rep(n_layers=1, n_encoder_layers=1), shape, mesh)
        c21 = _lower_compile(rep(n_layers=2, n_encoder_layers=1), shape, mesh)
        c12 = _lower_compile(rep(n_layers=1, n_encoder_layers=2), shape, mesh)
        tot = _lin(c11, c21, float(cfg.n_layers - 1) + 1.0)
        tot = _add(tot, _add(c12, c11, -1.0),
                   float(cfg.n_encoder_layers - 1))
        return tot
    if cfg.block_pattern == "zamba_hybrid":
        big = 10 ** 6
        c1 = _lower_compile(rep(n_layers=1, hybrid_attn_every=big),
                            shape, mesh)
        c2 = _lower_compile(rep(n_layers=2, hybrid_attn_every=big),
                            shape, mesh)
        c2a = _lower_compile(rep(n_layers=2, hybrid_attn_every=2),
                             shape, mesh)
        n_attn = cfg.n_layers // cfg.hybrid_attn_every
        tot = _lin(c1, c2, float(cfg.n_layers - 1))
        tot = _add(tot, _add(c2a, c2, -1.0), float(n_attn))
        return tot
    c1 = _lower_compile(rep(n_layers=1), shape, mesh)
    c2 = _lower_compile(rep(n_layers=2), shape, mesh)
    return _lin(c1, c2, float(cfg.n_layers - 1))


# ---------------------------------------------------------------------------
# The paper's own program on the production mesh: cGES ring
# ---------------------------------------------------------------------------

# (n, m, r_max) of the paper's three bnlearn domains (Table 1)
RING_DOMAINS = {
    "link_724": (724, 5000, 4),
    "pigs_441": (441, 5000, 3),
    "munin_1041": (1041, 5000, 5),
}


def run_ring_cell(domain: str, mesh_kind: str,
                  overrides: dict | None = None) -> dict:
    """Lower+compile cGES stage 2 (the shard_map ring) on the production
    mesh: ring processes over the 'data' axis (x'pod' multi-pod), scoring-TP
    over the 'model' axis inside each process.

    Roofline caveat (recorded): the ring is a while_loop program, so HLO
    cost analysis counts ONE round with ONE insert + ONE delete — the
    numbers below are per-round lower bounds, not per-run totals.
    """
    from ..core.ges import GESConfig
    from ..core.ring import RingSpec, build_ring_program
    from ..core.cges import edge_add_limit

    rec = {"arch": "cges_ring", "shape": domain, "mesh": mesh_kind,
           "ok": False}
    n, m, r_max = RING_DOMAINS[domain]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    ring_axis = ("pod", "data") if mesh_kind == "pod2" else "data"
    k = 32 if mesh_kind == "pod2" else 16

    ges_kw = dict(max_q=4096, counts_impl="segment", child_chunk=4,
                  max_parents=6)
    if overrides:
        ges_kw.update({k: v for k, v in overrides.items() if k in ges_kw})
        rec["overrides"] = overrides
    cfg = GESConfig(**ges_kw)
    spec = RingSpec(k=k, axis=ring_axis, max_rounds=16,
                    axis_model="model", axis_model_size=16)
    prog = build_ring_program(mesh, spec, cfg, r_max,
                              edge_add_limit(n, k), restricted=True)

    # Static E_i width for a balanced k-partition: ~n/k within-cluster
    # candidates per column plus ~n/k balanced cross edges (see
    # partition.pid_tables); the compiled ring's per-round sweep cost
    # tracks this W, not n.
    ring_w = max(1, min(n, -(-2 * n // k)))
    rec["ring_W"] = ring_w

    data = sp.sds((m, n), jnp.int32)
    arities = sp.sds((n,), jnp.int32)
    masks = sp.sds((k, n, n), jnp.int8)
    graphs0 = sp.sds((k, n, n), jnp.int8)
    pid_tables = sp.sds((k, n, ring_w), jnp.int32)

    t0 = time.time()
    with mesh:
        lowered = prog.lower(data, arities, masks, graphs0, pid_tables)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    costs = compiled.cost_analysis()
    if isinstance(costs, (list, tuple)):
        costs = costs[0]
    coll = collective_bytes(compiled.as_text())
    flops = _cost_get(costs, "flops")
    hbm = _cost_get(costs, "bytes accessed")
    terms = roofline_terms(flops, hbm, coll["total"])
    rec.update(
        ok=True, chips=mesh.devices.size, ring_k=k,
        seconds_lower=round(t_lower, 2),
        seconds_compile=round(t_compile, 2),
        flops_per_chip=flops, hbm_bytes_per_chip=hbm,
        collective_bytes_per_chip=coll["total"],
        collectives_full_hlo={kk: v for kk, v in coll.items() if v},
        memory=_memory_analysis_dict(compiled),
        note="per-round lower bound: while_loop body counted once",
        **terms,
    )
    return rec


def _parse_overrides(pairs):
    out = {}
    for kv in pairs or ():
        k, v = kv.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = {"true": True, "false": False}.get(v.lower(), v)
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             verbose: bool = True, skip_extrap: bool = False,
             overrides: dict | None = None) -> dict:
    if arch == "cges_ring":
        return run_ring_cell(shape_name, mesh_kind, overrides=overrides)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "ok": False}
    skip = cc.shape_applicable(arch, shape_name)
    if skip:
        rec.update(ok=True, skipped=True, reason=skip)
        return rec

    shape = cc.SHAPES[shape_name]
    cfg = cc.get_config(arch)
    if overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **overrides)
        rec["overrides"] = overrides
    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    n_chips = mesh.devices.size

    # 1) the deliverable: full-depth scanned program must lower+compile
    full = _lower_compile(cfg, shape, mesh)
    if verbose:
        print(f"[{arch}/{shape_name}/{mesh_kind}] memory:", full["memory"])

    rec.update(
        ok=True, chips=n_chips,
        seconds_lower=full["seconds_lower"],
        seconds_compile=full["seconds_compile"],
        memory=full["memory"],
        collectives_full_hlo=full["collectives"],
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
    )

    # 2) roofline terms from unrolled depth-1/2 extrapolation
    if not skip_extrap:
        ext = extrapolated_counts(cfg, shape, mesh)
        n_tokens = (shape.global_batch * shape.seq_len
                    if shape.kind != "decode" else shape.global_batch)
        mf_global = model_flops(cfg, shape.kind, n_tokens)
        mf_per_chip = mf_global / n_chips
        terms = roofline_terms(ext["flops"], ext["hbm_bytes"],
                               ext["coll_bytes"], useful_flops=mf_per_chip)
        rec.update(
            flops_per_chip=ext["flops"],
            hbm_bytes_per_chip=ext["hbm_bytes"],
            collective_bytes_per_chip=ext["coll_bytes"],
            model_flops_global=mf_global,
            model_flops_per_chip=mf_per_chip,
            useful_flops_ratio=(mf_per_chip / ext["flops"]
                                if ext["flops"] else 0.0),
            **terms,
        )
    return rec


def iter_cells(meshes):
    for arch in cc.ARCH_IDS:
        for shape in cc.SHAPES:
            for mk in meshes:
                yield arch, shape, mk


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-extrap", action="store_true",
                    help="compile-only (multi-pod cells: roofline table is "
                         "single-pod per the brief)")
    ap.add_argument("--set", action="append", dest="overrides",
                    help="config override key=value (perf variants; "
                         "recorded in the output line)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = (list(iter_cells(["pod1", "pod2"])) if args.all
             else [(args.arch, args.shape, args.mesh)])
    ok = True
    for arch, shape, mk in cells:
        try:
            rec = run_cell(arch, shape, mk,
                           skip_extrap=args.skip_extrap or mk == "pod2",
                           overrides=_parse_overrides(args.overrides))
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mk, "ok": False,
                   "error": repr(e),
                   "traceback": traceback.format_exc()[-2000:]}
            ok = False
        line = json.dumps(rec)
        print(line[:400] + ("..." if len(line) > 400 else ""))
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
