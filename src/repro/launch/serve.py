"""Batched serving driver: slot-based continuous batching over the decode
step (the production shape of `decode_32k`: many sequences, one new token
per step, KV/SSM caches resident).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_7b --smoke \
        --slots 4 --max-new 24

Design (scales to the pod path unchanged):
* a fixed pool of B cache slots (static shapes — one compiled step);
* each incoming request claims a free slot, prefill writes its KV rows via
  the same decode step replayed over the prompt (slot-local positions);
* every engine step decodes ALL active slots in one batched `serve_step`
  call; finished slots are freed and immediately reusable — arrival order
  never forces padding restarts;
* per-slot position vector instead of a global scalar: the step is
  batch-position-aware exactly as a production server needs.
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..models import transformer


def build_slot_serve_step(cfg):
    """Decode step with PER-SLOT positions: tokens (B, 1), pos (B,).

    `transformer.decode_step` takes a scalar fill position; continuous
    batching needs each slot at its own position, so we vmap the step over
    the cache's batch axis — each lane decodes its slot against its own
    cache row with its own scalar pos.  One compiled program, batch-parallel
    on device, exact per-slot causal windows.
    """
    cache_axes = {"k": 1, "v": 1, "xk": 1, "xv": 1, "attn_k": 1, "attn_v": 1,
                  "ssm": 1, "conv": 1}

    def one(params, cache_b, tok, p):
        # vmap stripped the batch axis from the cache leaves; decode_step
        # expects (L, B, ...) — run the lane at B=1 and strip back after.
        cache1 = jax.tree.map(lambda x: x[:, None], cache_b)
        logits, new_cache = transformer.decode_step(
            cfg, params, cache1, tok[None], p)
        new_cache = jax.tree.map(lambda x: x[:, 0], new_cache)
        return logits[0, -1, :], new_cache

    def step(params, cache, tokens, pos):
        axes = {k: v for k, v in cache_axes.items() if k in cache}
        logits, new_cache = jax.vmap(
            one, in_axes=(None, axes, 0, 0), out_axes=(0, axes),
        )(params, cache, tokens, pos)
        return logits, new_cache

    return step


class ServeEngine:
    """Slot-pool engine around one jitted batched decode step."""

    def __init__(self, cfg, params, n_slots: int, max_seq: int):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = transformer.init_cache(cfg, n_slots, max_seq)
        self.pos = np.zeros(n_slots, dtype=np.int32)
        self.active: List[Optional[dict]] = [None] * n_slots
        self._step = jax.jit(build_slot_serve_step(cfg))

    def submit(self, prompt: np.ndarray) -> Optional[int]:
        """Claim a slot and prefill it token-by-token (slot-local replay)."""
        try:
            slot = self.active.index(None)
        except ValueError:
            return None
        self.active[slot] = {"generated": [], "done": False}
        # prefill: replay prompt through the decode step for this slot only;
        # other slots decode a no-op token at their own positions (masked
        # out of their generated streams).
        for t in prompt:
            tokens = np.zeros((self.n_slots, 1), np.int32)
            tokens[slot, 0] = t
            self._advance(tokens, collect=False, only_slot=slot)
        return slot

    def _advance(self, tokens: np.ndarray, collect: bool = True,
                 only_slot: Optional[int] = None):
        # single compiled step for the whole pool: scalar pos per step is the
        # max; per-slot correctness comes from each slot's causal window
        # ending at its own fill position (positions vector).
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(tokens), pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        for s in range(self.n_slots):
            if only_slot is not None and s != only_slot:
                continue
            if self.active[s] is None:
                continue
            self.pos[s] += 1
            if collect:
                self.active[s]["generated"].append(int(nxt[s]))
        return nxt

    def step_all(self, last_tokens: np.ndarray):
        return self._advance(last_tokens.reshape(self.n_slots, 1))

    def free(self, slot: int):
        self.active[slot] = None
        self.pos[slot] = 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    eng = ServeEngine(cfg, params, args.slots, args.max_seq)

    rng = np.random.default_rng(0)
    pending = [rng.integers(0, cfg.vocab, size=rng.integers(4, 10))
               .astype(np.int32) for _ in range(args.requests)]
    done = 0
    t0 = time.time()
    last = np.zeros(args.slots, np.int32)
    while done < args.requests or any(a is not None for a in eng.active):
        while pending and None in eng.active:
            eng.submit(pending.pop(0))
        nxt = eng.step_all(last)
        last = nxt
        for s, a in enumerate(eng.active):
            if a and len(a["generated"]) >= args.max_new:
                print(f"slot {s}: {a['generated'][:8]}... "
                      f"({len(a['generated'])} tokens)")
                eng.free(s)
                done += 1
    dt = time.time() - t0
    total = args.requests * args.max_new
    print(f"served {args.requests} requests / {total} tokens "
          f"in {dt:.1f}s ({total / dt:.1f} tok/s on CPU smoke)")


if __name__ == "__main__":
    main()
