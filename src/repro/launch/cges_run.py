"""cGES driver — the paper's end-to-end workload with fault tolerance.

    PYTHONPATH=src python -m repro.launch.cges_run \
        --family link_like --scale 0.05 --k 4 --limit --ckpt-dir /tmp/cges

Engines:
* ``--engine host`` (default): the checkpointable host round loop below —
  ring processes are host tasks with jit-batched W-wide column sweeps.
* ``--engine ring``: the fully-compiled shard_map ring (core/ring.ring_cges)
  on a k-device mesh (host platform devices are forced to k when needed),
  with per-process static (n, W) pid_tables so every compiled round pays
  W = |E_i|-wide sweeps; the unrestricted fine-tune still runs on host.

Fusion on the host driver goes through the unified engine in
``core/fusion.py``: ``--fusion-engine {host,jit}`` (default from
REPRO_FUSION_ENGINE) picks the numpy or traceable implementation of the
per-round sigma-consistent edge union — the ring engine always traces the
same layer inside its compiled program.

Fault tolerance (1000-node posture, per DESIGN.md; host engine only):
* round-atomic checkpointing of the full ring state (k graphs + best score):
  a killed run resumes at the last completed round with identical results
  (the ring is deterministic given the partition);
* elastic ring repair: ``--fail-at-round R --fail-member i`` simulates a
  member loss; its edge subset E_i is re-merged into its ring predecessor
  (partition.remerge_failed) and the ring continues with k-1 members — the
  subsets stay a disjoint cover of E, so cGES's guarantees are unaffected.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from ..core import (DeviceFamilyCache, GESConfig, ScoreCache, bdeu, fusion,
                    ges_host, partition)
from ..core.cges import edge_add_limit
from ..core.dag import smhd_np
from ..data.bn import benchmark_bn, forward_sample
from . import devices


def ring_rounds(data, arities, edge_masks, config, add_limit, max_rounds,
                ckpt_dir=None, fail_at_round=None, fail_member=None,
                cache=None, verbose=True, fusion_engine=None,
                family_cache=None):
    """The learning stage as an explicit, checkpointable round loop.

    ``fusion_engine`` picks the host or traceable implementation of the
    unified sigma-consistent edge union (core/fusion.py) — identical
    adjacencies either way; ``None`` defaults from REPRO_FUSION_ENGINE.
    ``family_cache``: optional shared DeviceFamilyCache handle — the
    device-resident persistent column cache every member/round consults
    (trajectory-identical; see core/score_cache).
    """
    fusion_engine = fusion.resolve_fusion_engine(fusion_engine)
    k0, n, _ = edge_masks.shape
    graphs = [np.zeros((n, n), dtype=np.int8) for _ in range(edge_masks.shape[0])]
    best_score, best_adj = -np.inf, np.zeros((n, n), dtype=np.int8)
    start_round = 0
    cache = cache if cache is not None else ScoreCache()

    if ckpt_dir:
        os.makedirs(ckpt_dir, exist_ok=True)
        state_f = os.path.join(ckpt_dir, "ring_state.npz")
        if os.path.exists(state_f):
            z = np.load(state_f, allow_pickle=False)
            graphs = [z[f"g{i}"] for i in range(int(z["k"]))]
            edge_masks = z["masks"]
            best_score = float(z["best_score"])
            best_adj = z["best_adj"]
            start_round = int(z["round"])
            if verbose:
                print(f"resumed ring at round {start_round} (k={len(graphs)})")

    rnd = start_round
    go = True
    while go and rnd < max_rounds:
        k = edge_masks.shape[0]
        if fail_at_round is not None and rnd == fail_at_round and k > 1:
            fm = fail_member % k
            if verbose:
                print(f"[fault] member {fm} lost at round {rnd}: "
                      f"re-merging E_{fm} into its ring predecessor")
            edge_masks = partition.remerge_failed(edge_masks, fm)
            graphs.pop(fm)
            k -= 1
        new_graphs, new_scores = [], []
        for i in range(k):
            pred = graphs[(i - 1) % k]
            init = (np.zeros((n, n), dtype=np.int8) if rnd == 0
                    else fusion.fusion_edge_union(
                        graphs[i], pred, engine=fusion_engine).astype(np.int8))
            res = ges_host(data, arities, init_adj=init,
                           allowed=edge_masks[i], add_limit=add_limit,
                           config=config, cache=cache,
                           family_cache=family_cache)
            new_graphs.append(res.adj)
            new_scores.append(res.score)
        graphs = new_graphs
        rnd += 1
        round_best = max(new_scores)
        if round_best > best_score + config.tol:
            best_score = round_best
            best_adj = graphs[int(np.argmax(new_scores))].copy()
        else:
            go = False
        if verbose:
            print(f"round {rnd}: best BDeu {best_score:.2f} "
                  f"(round {round_best:.2f}, k={k})")
        if ckpt_dir:
            # np.savez appends .npz to names lacking it — keep the suffix
            tmp = os.path.join(ckpt_dir, "ring_state_tmp.npz")
            np.savez(tmp, k=len(graphs), masks=edge_masks,
                     best_score=best_score, best_adj=best_adj, round=rnd,
                     **{f"g{i}": g for i, g in enumerate(graphs)})
            os.replace(tmp, state_f)
    return best_adj, best_score, rnd, edge_masks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="link_like",
                    choices=["link_like", "pigs_like", "munin_like"])
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--m", type=int, default=2000)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--limit", action="store_true")
    ap.add_argument("--max-rounds", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--counts-impl", default="segment",
                    choices=["segment", "onehot", "pallas", "fused",
                             "fused_pallas"],
                    help="sweep-engine backend (core/sweeps): loop engines "
                         "build one table per candidate; fused* build one "
                         "joint contraction per insert column and one "
                         "marginalized family table per delete column — on "
                         "this host-engine driver both are restricted to "
                         "each process's E_i candidates (pids) before they "
                         "run")
    ap.add_argument("--engine", default="host", choices=["host", "ring"],
                    help="host: checkpointable host round loop; ring: the "
                         "fully-compiled shard_map ring with per-process "
                         "(n, W) pid_tables — compiled per-round sweep cost "
                         "tracks W = |E_i|, not n")
    ap.add_argument("--fusion-engine", default=None, choices=["host", "jit"],
                    help="engine for the per-round sigma-consistent edge "
                         "union on the host driver (core/fusion.py — the "
                         "same layer the compiled ring traces); default "
                         "reads REPRO_FUSION_ENGINE, else host.  Identical "
                         "adjacencies either way")
    ap.add_argument("--data-shards", type=int, default=1,
                    help="shard the instance (m) axis over this many devices "
                         "— each device contracts m/d rows into the count "
                         "tables and ONE psum merges them before the cheap "
                         "BDeu reduction (table-identical to 1).  host "
                         "engine: every sweep runs on a d-device data mesh; "
                         "ring engine: the mesh becomes 2-D (ring k x data "
                         "d) and needs k*d devices")
    ap.add_argument("--family-cache", action="store_true",
                    help="persistent device-resident family-score cache "
                         "(core/score_cache): memoises (child, parent-set) "
                         "columns across GES iterations, rounds and ring "
                         "members with prioritized eviction; trajectories "
                         "stay bitwise-identical.  Also via "
                         "REPRO_FAMILY_CACHE=1")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-at-round", type=int, default=None)
    ap.add_argument("--fail-member", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.engine == "ring" and (args.ckpt_dir or args.fail_at_round
                                  is not None):
        ap.error("--ckpt-dir / --fail-at-round are host-engine features")
    if args.data_shards < 1:
        ap.error("--data-shards must be >= 1")
    # Device requirement: the compiled ring needs k devices on its ring
    # axis, times d when the data axis is on; the host engine needs d for
    # its per-sweep data mesh (launch/devices.py re-execs once with forced
    # host devices when the initialized platform is too small).
    need = (args.k * args.data_shards if args.engine == "ring"
            else args.data_shards)
    devices.force_host_devices_or_reexec(need, "repro.launch.cges_run")

    t0 = time.time()
    bn = benchmark_bn(args.family, scale=args.scale, seed=args.seed)
    data = forward_sample(bn, args.m, np.random.default_rng(args.seed + 1))
    n = bn.n
    print(f"{args.family} scale={args.scale}: n={n}, m={args.m}")

    config = GESConfig(max_q=1024, counts_impl=args.counts_impl,
                       data_shards=(args.data_shards
                                    if args.engine == "host" else 1),
                       family_cache=(args.family_cache
                                     or GESConfig().family_cache))
    masks = partition.partition_edges(data, bn.arities, args.k)
    lim = edge_add_limit(n, args.k) if args.limit else None
    cache = ScoreCache()
    family_cache = (DeviceFamilyCache(n, config.cache_capacity)
                    if config.family_cache else None)

    ring_w = None
    ring_cache_stats = None
    if args.engine == "ring":
        from ..core.ring import RingSpec, ring_cges
        from .mesh import make_ring_data_mesh

        d = args.data_shards
        pid_tables = partition.pid_tables(masks)
        ring_w = int(pid_tables.shape[2])
        mesh = make_ring_data_mesh(args.k, d)
        spec = (RingSpec(k=args.k, max_rounds=args.max_rounds,
                         data_axis="data", data_axis_size=d) if d > 1
                else RingSpec(k=args.k, max_rounds=args.max_rounds))
        out_ring = ring_cges(
            data, bn.arities, masks, mesh, spec, config,
            add_limit=lim, pid_tables=pid_tables,
            return_cache_stats=config.family_cache)
        graphs, scores, rounds = out_ring[0], out_ring[1], out_ring[2]
        if config.family_cache:
            ring_cache_stats = out_ring[3]
        adj = graphs[int(np.argmax(scores))]
        print(f"compiled ring: {rounds} rounds, W={ring_w} "
              f"(restricted sweep width vs n={n}, data shards={d})")
    else:
        adj, score, rounds, masks = ring_rounds(
            data, bn.arities, masks, config, lim, args.max_rounds,
            ckpt_dir=args.ckpt_dir, fail_at_round=args.fail_at_round,
            fail_member=args.fail_member, cache=cache,
            fusion_engine=args.fusion_engine, family_cache=family_cache)

    # fine-tuning pass (unrestricted GES) — carries GES's guarantees
    res = ges_host(data, bn.arities, init_adj=adj, allowed=None,
                   add_limit=None, config=config, cache=cache,
                   family_cache=family_cache)
    wall = time.time() - t0
    out = {
        "family": args.family, "n": n, "m": args.m, "k": args.k,
        "engine": args.engine,
        "limit": bool(args.limit), "rounds": rounds,
        "bdeu_per_instance": res.score / args.m,
        "smhd_vs_truth": smhd_np(res.adj, bn.adj),
        "wall_s": round(wall, 2),
        "cache_hits": cache.hits, "cache_misses": cache.misses,
        "data_shards": args.data_shards,
    }
    if ring_w is not None:
        out["ring_W"] = ring_w
    if family_cache is not None:
        out["family_cache"] = family_cache.stats()
    if ring_cache_stats is not None:
        out["ring_family_cache"] = ring_cache_stats
    print(json.dumps(out, indent=2))
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(out) + "\n")


if __name__ == "__main__":
    main()
