"""Production meshes.  A FUNCTION (not module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import numpy as np
import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) 'data' x 'model' single-pod (256 chips, TPU v5e pod) or
    (2, 16, 16) 'pod' x 'data' x 'model' (512 chips, 2 pods).

    Requires enough devices (the dry-run forces 512 host devices via
    XLA_FLAGS *before* jax init); uses the first prod(shape) of them.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"production mesh needs {n} devices, have {len(devs)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import (launch/dryrun.py does this)")
    return jax.sharding.Mesh(
        np.asarray(devs[:n], dtype=object).reshape(shape), axes)


def make_host_mesh(k: int = 1, axis: str = "ring"):
    """k-device 1-axis mesh from whatever devices exist (tests / cGES ring)."""
    devs = jax.devices()[:k]
    return jax.sharding.Mesh(np.asarray(devs, dtype=object).reshape(k), (axis,))


def make_ring_data_mesh(k: int, d: int = 1):
    """(k,) 'ring' mesh, or the 2-D (k, d) 'ring' x 'data' mesh the compiled
    ring uses when the instance axis is sharded over d devices per member
    (core/ring.RingSpec(data_axis=...)).  Needs k*d devices — force host
    devices first (launch/devices.force_host_devices_or_reexec)."""
    devs = jax.devices()
    if len(devs) < k * d:
        raise RuntimeError(
            f"ring x data mesh needs k*d={k * d} devices, have {len(devs)}")
    if d > 1:
        return jax.sharding.Mesh(
            np.asarray(devs[:k * d], dtype=object).reshape(k, d),
            ("ring", "data"))
    return make_host_mesh(k)
