"""End-to-end LM training driver with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train \
        --arch mamba2_130m --smoke --steps 50 --ckpt-dir /tmp/ckpt

* deterministic data (repro.data.tokens): restart replays identical batches;
* step-atomic checkpoints every --ckpt-every steps; --resume picks up the
  newest complete checkpoint (kill -9 mid-run and rerun to test);
* on a device mesh the same step function runs pjit'd with the sharding
  trees from launch/specs.py — here it runs single-device (CPU smoke).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..data.tokens import DataConfig, TokenPipeline
from ..models import transformer
from ..training import AdamWConfig, build_train_step, init_opt_state
from ..training.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_130m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    pipe = TokenPipeline(dcfg)

    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    opt_state = init_opt_state(params)
    start_step = 0

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep_last=3)
        if args.resume and mgr.latest() is not None:
            s = mgr.latest()
            params, opt_state, man = mgr.restore(s, params, opt_state)
            start_step = s
            print(f"resumed from step {s}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=args.steps)
    step_fn = jax.jit(build_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = pipe.batch_at(step)
        if cfg.enc_dec:
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.frontend_tokens, cfg.frontend_dim),
                jnp.bfloat16)
        if cfg.frontend == "vision_stub":
            batch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == start_step:
            print(f"step {step + 1:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0) / (step - start_step + 1):.2f}s/it)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, params, opt_state,
                     {"loss": float(metrics["loss"])})
    if mgr:
        mgr.save(args.steps, params, opt_state, {})
        mgr.wait()
    print("done")


if __name__ == "__main__":
    main()
