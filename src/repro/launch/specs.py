"""Abstract input specs (ShapeDtypeStruct) + sharding trees for every
(arch x shape) cell — the dry-run's stand-ins; no device allocation.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import sharding as shd
from ..models import transformer
from ..models.config import ModelConfig
from ..configs.common import ShapeSpec
from ..training.optimizer import init_opt_state


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, kind: str):
    """(abstract_batch, partition_spec_tree) for train/prefill batches."""
    b, t = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, t), jnp.int32)}
    if kind == "train":
        batch["labels"] = sds((b, t), jnp.int32)
    if cfg.enc_dec:
        batch["frames"] = sds((b, cfg.frontend_tokens, cfg.frontend_dim),
                              jnp.bfloat16)
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = sds((b, cfg.frontend_tokens, cfg.d_model),
                                    jnp.bfloat16)
    return batch


def batch_shard_tree(batch, mesh: Mesh, cfg: ModelConfig | None = None):
    axes = shd.dp_axes(mesh)
    # TP-less archs: fold 'model' into the batch axes when divisible, so the
    # model axis does useful (not redundant) work (§Perf iteration 4)
    if cfg is not None and getattr(cfg, "dp_over_model", False) \
            and "model" in mesh.axis_names:
        axes = axes + ("model",)

    def size_of(ax):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n

    def spec_for(leaf):
        ax = axes
        while ax and (not leaf.shape or leaf.shape[0] % size_of(ax)):
            ax = ax[:-1]                      # drop axes until divisible
        if ax and leaf.shape:
            return P(ax, *(None,) * (len(leaf.shape) - 1))
        return P(*(None,) * len(leaf.shape))

    return jax.tree.map(lambda l: NamedSharding(mesh, spec_for(l)), batch)


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in shd.dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        partial(transformer.init_params, cfg=cfg), jax.random.PRNGKey(0))


def abstract_opt(params_shape):
    return jax.eval_shape(init_opt_state, params_shape)


def abstract_cache(cfg: ModelConfig, batch: int, seq: int):
    return jax.eval_shape(
        partial(transformer.init_cache, cfg, batch, seq))


def param_shardings(cfg, mesh, params_shape):
    return shd.to_named(mesh, shd.param_specs(cfg, mesh, params_shape))


def opt_shardings(cfg, mesh, params_shape):
    mspec = shd.opt_specs(cfg, mesh, params_shape)
    return {"m": shd.to_named(mesh, mspec),
            "v": shd.to_named(mesh, mspec),
            "count": NamedSharding(mesh, P())}


def cache_shardings(cfg, mesh, cache_shape, shard_seq: bool):
    return shd.to_named(
        mesh, shd.cache_specs(cfg, mesh, cache_shape, shard_seq=shard_seq))
