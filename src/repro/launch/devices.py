"""Forced-host-device and multi-process environment plumbing, shared by the
launchers.

XLA fixes its device count when the backend initializes — which importing
``repro.core`` already did by the time a driver parses its arguments — so a
driver that discovers it needs a wider host platform must re-exec itself
once with ``--xla_force_host_platform_device_count`` in ``XLA_FLAGS``.
That logic used to be grown ad hoc per flag (``--engine ring``,
``--data-shards``, ``--family-cache``) inside ``cges_run``; it lives here
now, and the same helper carries the ``jax.distributed`` coordinator
environment for the multi-process async-ring launch path
(``launch/ring_async_run.py``).
"""
from __future__ import annotations

import os
import sys
from typing import Dict, List, Optional


def force_host_devices_or_reexec(
    need: int,
    module: str,
    argv: Optional[List[str]] = None,
    extra_env: Optional[Dict[str, str]] = None,
) -> None:
    """Ensure at least ``need`` jax devices exist, re-exec'ing
    ``python -m <module> <argv>`` once with forced host devices if the
    already-initialized platform is too small.

    ``extra_env`` entries are exported before the re-exec (e.g. the
    ``jax.distributed`` coordinator triplet for a multi-process launch).
    Raises ``SystemExit`` if the device count was already forced and is
    still too small — re-exec'ing again would loop forever.
    """
    if need <= 1:
        return
    import jax

    if len(jax.devices()) >= need:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" in flags:
        raise SystemExit(
            f"{module} needs >= {need} devices, found {len(jax.devices())} "
            f"(host platform device count already forced: {flags!r})")
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={need}").strip()
    for key, val in (extra_env or {}).items():
        os.environ[key] = val
    os.execv(sys.executable,
             [sys.executable, "-m", module]
             + (sys.argv[1:] if argv is None else argv))


def jax_distributed_env(coordinator: str, num_processes: int,
                        process_id: int) -> Dict[str, str]:
    """The env triplet a ring-async worker consumes to join the optional
    ``jax.distributed`` cluster (cluster formation only on the CPU backend —
    cross-process collectives aren't implemented there, and the coordination
    service hard-terminates survivors when a peer dies, so the data plane
    stays on our own sockets; see core/ring_async.py)."""
    return {
        "REPRO_JAX_COORDINATOR": coordinator,
        "REPRO_JAX_NUM_PROCS": str(int(num_processes)),
        "REPRO_JAX_PROC_ID": str(int(process_id)),
    }
