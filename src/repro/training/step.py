"""Loss + train_step builders (arch-generic; shardings applied by caller)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import transformer
from .optimizer import AdamWConfig, adamw_update

MOE_AUX_COEF = 0.01


def lm_loss(cfg, params, batch) -> jax.Array:
    """Mean next-token cross-entropy.  batch: dict with ``tokens`` (B, T)
    [+ ``labels``; + ``frames``/``patch_embeds`` for enc-dec / VLM stubs]."""
    logits, aux = transformer.forward(
        cfg, params, batch["tokens"],
        frames=batch.get("frames"), patch_embeds=batch.get("patch_embeds"))
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    return ce + MOE_AUX_COEF * aux


def build_train_step(cfg, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch))(params)
        new_params, new_state, metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def build_eval_step(cfg):
    def eval_step(params, batch):
        return lm_loss(cfg, params, batch)
    return eval_step
