"""AdamW in plain JAX pytrees (no external deps).

Moments are f32 regardless of param dtype; the ZeRO-1 sharding of the moment
trees is decided by ``models.sharding.opt_specs`` (shard over 'data'), which
makes XLA lower the update into reduce-scatter(grad) -> local update ->
all-gather(param) — the standard distributed-optimizer schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, count)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** count.astype(jnp.float32))
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
