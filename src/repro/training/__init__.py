from .optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from .step import build_eval_step, build_train_step, lm_loss
