"""Step-atomic checkpointing (fault tolerance for 1000+ node runs).

Design (scales to multi-host by construction):
* every leaf saved as a .npy inside one .npz per tree, keyed by flattened
  path — layout-independent of the pytree's Python types;
* write-to-temp + atomic ``os.replace`` of the manifest: a checkpoint either
  exists completely or not at all (a killed writer leaves only a ``.tmp``);
* ``keep_last`` pruning; ``latest()`` picks the newest complete manifest;
* restart determinism: the data pipeline is stateless in ``step`` (see
  repro.data.tokens), so restoring {params, opt_state, step} replays the
  exact batch sequence.

On a real multi-host deployment each host writes its local shards via the
same protocol (path gains a ``proc{i}`` suffix) — the atomic-manifest commit
is the cross-host barrier; here (single-process) that degenerates to one file.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import numpy as np
import jax


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz has no bf16: store as f32
            arr = arr.astype(np.float32)   # (lossless; cast back on restore)
        flat[key] = arr
    return flat


def _unflatten_like(tree, flat: dict):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path)
        arr = flat[key]
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    treedef = jax.tree_util.tree_structure(tree)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 async_write: bool = False):
        self.dir = directory
        self.keep_last = keep_last
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, params, opt_state, extra: Optional[dict] = None):
        if self.async_write:
            self.wait()
            host_p = jax.device_get(params)
            host_o = jax.device_get(opt_state)
            self._thread = threading.Thread(
                target=self._write, args=(step, host_p, host_o, extra))
            self._thread.start()
        else:
            self._write(step, params, opt_state, extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, params, opt_state, extra):
        tag = f"step_{step:010d}"
        tmp = os.path.join(self.dir, tag + ".tmp")
        final = os.path.join(self.dir, tag)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
        np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
        manifest = {"step": step, "time": time.time(), "extra": extra or {},
                    "files": ["params.npz", "opt_state.npz"]}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                     # atomic commit
        self._prune()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, params_like, opt_like) -> Tuple[Any, Any, dict]:
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        pz = np.load(os.path.join(d, "params.npz"))
        oz = np.load(os.path.join(d, "opt_state.npz"))
        params = _unflatten_like(params_like, dict(pz))
        opt = _unflatten_like(opt_like, dict(oz))
        return params, opt, manifest
