"""Distributed-optimization extras: int8 gradient compression with error
feedback + a ring all-reduce built from the paper's own topology.

The cGES ring (core/ring.py) passes an (n, n) adjacency around a mesh axis
with ``lax.ppermute``; the same primitive gives a bandwidth-optimal ring
all-reduce (reduce-scatter ring pass + all-gather ring pass), which composes
with int8 quantization to cut DP gradient traffic 4x vs f32 / 2x vs bf16:

    compressed, err = quantize_int8(grad + err_feedback)
    allreduced      = ring_allreduce(compressed)      # int8 on the wire

Error feedback keeps the quantization *unbiased over time* (the residual is
re-added next step), the standard trick that keeps convergence intact.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize_int8(x: Array) -> Tuple[Array, Array]:
    """Symmetric per-tensor int8.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grad: Array, err: Array) -> Tuple[Array, Array, Array]:
    """(q, scale, new_err): quantize grad+err, carry the residual forward."""
    g = grad.astype(jnp.float32) + err
    q, scale = quantize_int8(g)
    new_err = g - dequantize_int8(q, scale)
    return q, scale, new_err


def ring_allreduce(x: Array, axis: str, k: int) -> Array:
    """Bandwidth-optimal ring all-reduce via 2(k-1) ppermute hops.

    x: per-device array whose leading dim is padded to k chunks.  Per-device
    traffic = 2 * (k-1)/k * |x| — the paper's ring topology as a gradient
    exchange.  (Didactic reference; production uses lax.psum, which XLA
    lowers to the same schedule on TPU tori.)
    """
    n = x.shape[0]
    pad = (-n) % k
    xp = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    chunks = xp.reshape(k, -1, *x.shape[1:]).astype(jnp.float32)
    idx = jax.lax.axis_index(axis)
    fwd = [(i, (i + 1) % k) for i in range(k)]

    # reduce-scatter pass: after k-1 hops device i holds the full sum of
    # chunk (i+1) % k
    def rs_step(s, carry):
        acc, buf = carry
        buf = jax.lax.ppermute(buf, axis, fwd)
        take = chunks[(idx - s - 1) % k]      # chunk arriving this hop
        buf = buf + take
        return (acc, buf), None

    buf0 = chunks[idx]
    (_, reduced), _ = jax.lax.scan(
        lambda c, s: rs_step(s, c), (None, buf0), jnp.arange(k - 1))

    # all-gather pass: circulate the reduced chunks.  After s forward hops
    # the buffer on device i is the chunk that started on device i-s, i.e.
    # chunk ((i - s) + 1) mod k.
    def ag_step(carry, s):
        out, buf = carry
        buf = jax.lax.ppermute(buf, axis, fwd)
        out = out.at[(idx + 1 - s) % k].set(buf)
        return (out, buf), None

    out0 = jnp.zeros_like(chunks).at[(idx + 1) % k].set(reduced)
    (gathered, _), _ = jax.lax.scan(
        ag_step, (out0, reduced), jnp.arange(1, k))
    flat = gathered.reshape(-1, *x.shape[1:])[:n]
    return flat.astype(x.dtype)


def compressed_psum(grad: Array, err: Array, axis: str) -> Tuple[Array, Array]:
    """int8-on-the-wire DP gradient sum with error feedback.

    Quantize (with feedback), all-reduce the int8 payload + f32 scale, then
    dequantize: wire bytes drop 4x vs f32.  Returns (summed_grad, new_err).
    """
    q, scale, new_err = compress_with_feedback(grad, err)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis)   # int payload
    scale_max = jax.lax.pmax(scale, axis)             # shared scale bound
    return q_sum.astype(jnp.float32) * scale_max, new_err
