"""Evaluation metrics used in the paper's Tables 2a-2c."""
from __future__ import annotations

import numpy as np

from . import bdeu
from .dag import moral_graph_np, smhd_np, shd_np  # re-exported


def normalized_bdeu(
    data: np.ndarray, arities: np.ndarray, adj: np.ndarray, ess: float = 10.0
) -> float:
    """BDeu / m — the per-instance normalization of Teyssier & Koller used by
    the paper's Table 2a."""
    return bdeu.graph_score_np(data, arities, adj, ess) / data.shape[0]


def empty_graph_bdeu(data: np.ndarray, arities: np.ndarray, ess: float = 10.0) -> float:
    n = data.shape[1]
    return bdeu.graph_score_np(data, arities, np.zeros((n, n), np.int8), ess)
