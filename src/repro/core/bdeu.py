"""BDeu scoring — the compute hot-spot of GES/cGES.

Two mirrored engines with identical semantics:

* **host** (numpy, sparse-exact): contingency tables via ``np.unique`` over the
  *observed* parent configurations only.  Valid for arbitrary arities / parent
  set sizes; this is the oracle used in tests and the default for paper-scale
  host orchestration.

* **device** (jnp, dense-padded, jit-safe): parent sets are padded to a static
  ``max_parents`` with phantom arity-1 slots, contingency tables are dense
  ``(max_q, r_max)`` arrays built either by ``segment_sum`` or by a one-hot
  matmul (the MXU-friendly TPU path; see ``repro.kernels.bdeu_count``).
  Configurations with zero counts contribute exactly 0 to the BDeu sum
  (lgamma(a) - lgamma(0 + a) == 0), so dense padding is *exact*, not an
  approximation.

The device engine's candidate sweeps additionally have a **fused** mode
(``counts_impl="fused"`` / ``"fused_pallas"``):

* insert (FES): all n candidate contingency tables of a child are produced by
  ONE joint (child-value-batched) one-hot contraction instead of n independent
  builds — see the "Fused all-candidate sweep engine" section below and
  ``repro.kernels.bdeu_sweep`` for the tiled Pallas realization.  With a
  candidate subset ``pids`` (the ring's restricted E_i), the candidate data
  columns are gathered *before* the contraction so the fused cost scales with
  W = |pids|, not n.
* delete (BES): every candidate table ``counts(Pa - {x})`` is a
  *marginalization* of the ONE current-family (q0, r) table over parent slot
  x — :func:`fused_delete_scores` builds that table once and reads the whole
  delete column off it with zero re-counting (n table builds -> 1).  Under
  ``"fused_pallas"`` the build, the per-slot marginalizations and the BDeu
  reductions all happen inside ONE VMEM-resident Pallas kernel
  (``kernels/bdeu_sweep.delete_scores``), so the table never round-trips
  through HBM and only the (n,)/(W,) score column is written back.

The unified caller-facing layer over these primitives is ``repro.core.sweeps``.

The BDeu local score of child i with parent set Pa (Heckerman et al. 1995):

    sum_j [ lgamma(ess/q) - lgamma(N_ij + ess/q) ]
  + sum_jk [ lgamma(N_ijk + ess/(q r)) - lgamma(ess/(q r)) ]

with q = prod of parent arities, r = arity of the child.  A uniform structure
prior is used (log P(G) = 0), as is standard.
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

Array = jax.Array

_lgamma_np = np.frompyfunc(math.lgamma, 1, 1)


def lgamma_np(x: np.ndarray) -> np.ndarray:
    """Exact (libm) log-gamma on host arrays."""
    return _lgamma_np(np.asarray(x, dtype=np.float64)).astype(np.float64)


# ---------------------------------------------------------------------------
# Host engine — sparse exact
# ---------------------------------------------------------------------------

def local_score_np(
    data: np.ndarray,
    arities: np.ndarray,
    child: int,
    parents: Sequence[int],
    ess: float = 10.0,
) -> float:
    """Exact BDeu local score of ``child`` given ``parents`` on host.

    data: (m, n) int array of category indices; arities: (n,).
    Only observed parent configurations are materialized (zero-count
    configurations contribute 0 by cancellation).
    """
    parents = list(parents)
    r = int(arities[child])
    q = 1
    for p in parents:
        q *= int(arities[p])
    if parents:
        # radix-encode observed parent configurations
        cfg = np.zeros(data.shape[0], dtype=np.int64)
        for p in parents:
            cfg = cfg * int(arities[p]) + data[:, p]
        uniq, inv = np.unique(cfg, return_inverse=True)
        flat = inv * r + data[:, child]
        counts = np.bincount(flat, minlength=uniq.size * r).reshape(uniq.size, r)
    else:
        counts = np.bincount(data[:, child], minlength=r).reshape(1, r)
    n_ij = counts.sum(axis=1)
    a_j = ess / q
    a_jk = ess / (q * r)
    term_j = lgamma_np(np.full_like(n_ij, a_j, dtype=np.float64)) - lgamma_np(n_ij + a_j)
    term_jk = lgamma_np(counts + a_jk) - lgamma_np(np.full_like(counts, a_jk, dtype=np.float64))
    return float(term_j.sum() + term_jk.sum())


def graph_score_np(
    data: np.ndarray, arities: np.ndarray, adj: np.ndarray, ess: float = 10.0
) -> float:
    """Total BDeu of a DAG = sum of local scores (decomposability)."""
    total = 0.0
    for y in range(adj.shape[0]):
        total += local_score_np(data, arities, y, list(np.flatnonzero(adj[:, y])), ess)
    return total


def pairwise_similarity_np(
    data: np.ndarray, arities: np.ndarray, ess: float = 10.0
) -> np.ndarray:
    """Paper Eq. (4):  s(X_i, X_j) = BDeu(X_i <- X_j) - BDeu(X_i, no parent).

    Returned matrix is symmetrized (the measure is symmetric up to finite-sample
    noise; the paper treats it as symmetric).
    """
    n = data.shape[1]
    s = np.zeros((n, n), dtype=np.float64)
    base = np.array([local_score_np(data, arities, i, [], ess) for i in range(n)])
    for i in range(n):
        for j in range(i + 1, n):
            sij = local_score_np(data, arities, i, [j], ess) - base[i]
            sji = local_score_np(data, arities, j, [i], ess) - base[j]
            s[i, j] = s[j, i] = 0.5 * (sij + sji)
    return s


# ---------------------------------------------------------------------------
# Device engine — dense padded, jit-safe
# ---------------------------------------------------------------------------

def _slot_encode(data: Array, arities: Array, parent_mask: Array):
    """Radix-encode parent configurations for a *masked* parent set.

    parent_mask: (n,) bool — which variables are parents.  Masked-out variables
    become phantom arity-1 slots (value 0), so the true q is the product of the
    selected arities and the config index stays < q.

    Returns (cfg, q): cfg (m,) int32 config index, q scalar int32 (true q).
    """
    # int32 radix encoding: valid whenever the true q fits the dense table
    # bound (max_q << 2^31); overflowing candidates are masked to -inf by the
    # log-domain guard in local_score_masked, and their (wrapped) cfg values
    # are clipped before counting, so they never corrupt memory or counts.
    #
    # Fully vectorized (no sequential scan over the n slots): the Horner
    # recurrence cfg = ((0*ar_0 + v_0)*ar_1 + v_1)... expands to
    # sum_i v_i * prod_{j>i} ar_j, and int32 arithmetic is exact modular
    # arithmetic, so the place-value sum is BITWISE identical to the scan it
    # replaces — including on wrapping (guarded) parent sets.  This keeps the
    # per-column cost of a *restricted* W-wide sweep from being dominated by
    # an O(n)-step sequential encode.
    slot_ar = jnp.where(parent_mask, arities, 1).astype(jnp.int32)
    slot_val = jnp.where(parent_mask[None, :], data, 0).astype(jnp.int32)
    rev = jnp.cumprod(slot_ar[::-1])                 # prod of trailing slots
    q = rev[-1]
    low = jnp.concatenate([rev[::-1][1:], jnp.ones(1, jnp.int32)])
    cfg = (slot_val * low[None, :]).sum(axis=1, dtype=jnp.int32)
    return cfg, q


def _bdeu_from_counts(counts: Array, q, r, ess: float) -> Array:
    """BDeu sum given dense ``(..., Q, R)`` count tables and true q, r.

    Rows >= q and columns >= r are guaranteed zero-count; zero-count cells
    cancel exactly, but the *per-row* ``lgamma(ess/q) - lgamma(N_ij + ess/q)``
    term is also exactly 0 for empty rows, so no masking is needed beyond using
    the true q, r in the hyperparameters.

    Vectorized over leading batch dims: ``q`` may carry the same batch shape
    as ``counts[..., 0, 0]`` (the fused sweep passes the per-candidate
    ``q0 * r_x`` vector and reduces a whole ``(n, Q, R)`` slab to the ``(n,)``
    score column in one shot); scalar ``q``/``r`` recovers the single-family
    behaviour.
    """
    q = jnp.asarray(q).astype(jnp.float32)
    r = jnp.asarray(r).astype(jnp.float32)
    a_j = (ess / q)[..., None]
    a_jk = (ess / (q * r))[..., None, None]
    n_ij = counts.sum(axis=-1)
    term_j = gammaln(a_j) - gammaln(n_ij + a_j)
    term_jk = gammaln(counts + a_jk) - gammaln(a_jk)
    return term_j.sum(-1) + term_jk.sum((-2, -1))


def _psum_counts(counts: Array, data_axis_name: str | None) -> Array:
    """Contingency tables are additive over instances: when the m axis is
    sharded over ``data_axis_name`` each device builds its partial table and
    ONE psum reconstructs the global counts — placed here, before the
    (m-independent) BDeu reduction, so the reduction itself never needs to
    know about the mesh."""
    if data_axis_name is None:
        return counts
    return jax.lax.psum(counts, data_axis_name)


def _dense_counts_segment(cfg: Array, child_col: Array, r_max: int, max_q: int) -> Array:
    """(max_q, r_max) contingency table via segment-sum (CPU/debug path).

    Out-of-range child values (the data-axis sharder pads ragged m with
    sentinel rows of value r_max, out of range for every variable) are routed
    to an explicit overflow segment and sliced off — same OOB-drop idiom as
    ``kernels/bdeu_sweep/ref.py``; bitwise-identical for in-range rows.
    """
    ok = (child_col >= 0) & (child_col < r_max)
    flat = jnp.where(ok, jnp.clip(cfg, 0, max_q - 1) * r_max + child_col,
                     max_q * r_max)
    counts = jax.ops.segment_sum(
        jnp.ones_like(flat, dtype=jnp.float32), flat,
        num_segments=max_q * r_max + 1
    )[: max_q * r_max]
    return counts.reshape(max_q, r_max)


def _dense_counts_onehot(cfg: Array, child_col: Array, r_max: int, max_q: int) -> Array:
    """(max_q, r_max) contingency table as one-hot matmul — MXU-friendly.

    counts = OH(cfg)^T @ OH(child):  (max_q, m) @ (m, r_max).  Exact for
    m <= 2^24 in f32.  This is the TPU-native replacement for GPU scatter-add;
    the Pallas kernel in repro/kernels/bdeu_count tiles the same contraction.
    (Sentinel rows with child = r_max one-hot to the zero row — counting-
    neutral without any explicit guard.)
    """
    cfg = jnp.clip(cfg, 0, max_q - 1)
    oh_cfg = jax.nn.one_hot(cfg, max_q, dtype=jnp.float32)
    oh_child = jax.nn.one_hot(child_col, r_max, dtype=jnp.float32)
    return oh_cfg.T @ oh_child


# ---------------------------------------------------------------------------
# Fused all-candidate sweep engine
# ---------------------------------------------------------------------------
#
# The FES candidate sweep for child y evaluates n families (Pa_y + {x}) at
# once.  The extended parent configuration factorizes,
#
#     cfg_x = (cfg0, X_x)        for every candidate x simultaneously,
#
# so instead of n per-candidate table builds the whole sweep is ONE joint
# contraction over the batched index (child value b, base config j0):
#
#     counts[b, j0, x*r_max + a] = #(child = b, cfg0 = j0, X_x = a)
#                                = OH(cfg0 | child=b)^T @ OH_all(data)
#
# r_max small (max_q, m) @ (m, n*r_max) matmuls (the Pallas kernel in
# repro/kernels/bdeu_sweep) or one segment-sum of the (m, n*r_max) one-hot
# (the jnp reference below).  The per-candidate (Q, R) table is the slice
# counts[:, :, x*r_max:(x+1)*r_max] with rows (j0, a) — an injective
# relabeling of the radix codes cfg0 * r_x + X_x, and BDeu depends only on
# the partition the codes induce, so the non-canonical order is exact.
# Rows with a >= r_x, j0 >= q0 or b >= r_y have zero counts and cancel
# exactly (lgamma(N + a) - lgamma(a) = 0 at N = 0): dense padding is exact.
#
# Roofline (paper scale n=400, m=5000, max_q=4096, r=4): the per-candidate
# loop issues n memory-bound builds with r_max=4 result columns (4/128 MXU
# lanes used); the fused sweep issues r_max MXU-shaped contractions with
# n*r_max = 1600 result columns, ~n/r_max = 100x fewer dispatches per child
# and ~full lane utilization — compute goes from latency-bound scatter/matmul
# dribble to a handful of dense GEMMs (2*m*max_q*n*r_max ~ 2.6e11 flop per
# child sweep, ~3 ms at 100 Tflop/s).

FUSED_IMPLS = ("fused", "fused_pallas")

# Every legal sweep backend.  Dispatch sites fall through to the segment
# engine for anything unrecognized, so entry points (GESConfig, sweeps.sweep)
# validate against this list up front — a typo'd impl (e.g. in the CI
# matrix's REPRO_COUNTS_IMPL) must fail loudly, not silently run "segment".
COUNTS_IMPLS = ("segment", "onehot", "pallas") + FUSED_IMPLS


def check_counts_impl(counts_impl: str) -> str:
    if counts_impl not in COUNTS_IMPLS:
        raise ValueError(
            f"unknown counts_impl {counts_impl!r}; valid: {COUNTS_IMPLS} "
            f"(did REPRO_COUNTS_IMPL or a config typo sneak through?)")
    return counts_impl

# Fused impls accelerate the *candidate sweeps* (insert + delete); everywhere a
# single family is scored (base scores, graph totals, the one family-table
# build of the fused delete sweep) they degrade to the matching per-family
# engine.
_SINGLE_IMPL = {"fused": "segment", "fused_pallas": "pallas"}


def single_impl(counts_impl: str) -> str:
    """Per-family counts engine backing a (possibly fused) counts_impl."""
    return _SINGLE_IMPL.get(counts_impl, counts_impl)


def _onehot_all(data: Array, r_max: int) -> Array:
    """(m, n*r_max) padded one-hot of every data column — child-independent,
    so full sweeps hoist it out of the per-child map."""
    m, n = data.shape
    return jax.nn.one_hot(data, r_max, dtype=jnp.float32).reshape(m, n * r_max)


def _sweep_counts_segment(cfg0: Array, child_col: Array, oh_all: Array,
                          max_q: int, r_max: int) -> Array:
    """Joint sweep counts (r_max, max_q, n*r_max) via one segment-sum.

    counts[b, j0, x*r_max + a] = #(child=b, cfg0=j0, X_x=a).  The jnp
    reference for the bdeu_sweep Pallas kernel; ``oh_all`` is the
    (m, n*r_max) data one-hot from :func:`_onehot_all`.

    Sentinel rows (child = r_max, from the data-axis sharder's ragged-m
    padding) are routed to an explicit overflow segment and sliced off —
    bitwise-identical routing for in-range rows.
    """
    ok = (child_col >= 0) & (child_col < r_max)
    idx = jnp.where(ok, child_col * max_q + jnp.clip(cfg0, 0, max_q - 1),
                    r_max * max_q)
    counts = jax.ops.segment_sum(
        oh_all, idx, num_segments=r_max * max_q + 1)[: r_max * max_q]
    return counts.reshape(r_max, max_q, oh_all.shape[1])


def fused_insert_scores(
    data: Array,
    arities: Array,
    child: Array,
    parent_mask: Array,
    ess: float,
    max_q: int,
    r_max: int,
    counts_impl: str = "fused",
    oh_all: Array | None = None,
    pids: Array | None = None,
    data_axis_name: str | None = None,
) -> Array:
    """(n,) BDeu scores of ALL candidate families (Pa + {x}) for one child.

    One joint contraction replaces the n per-candidate table builds of the
    loop engine (see the section comment above for the factorized-config
    encoding and the exactness-by-cancellation argument).  Entry x holds
    score(child, Pa + {x}); candidates whose extended parent set overflows
    the static table bound (q0 * r_x > max_q) are -inf.  Entries at
    x == child or x already in Pa are scored with the duplicated slot
    (q = q0 * r_x) — garbage by convention; ``repro.core.sweeps`` masks them
    before any caller sees the column.

    ``pids``: optional (W,) candidate subset — the ring's restricted E_i.
    The W candidate data columns are gathered BEFORE the joint contraction,
    so the contraction width (and the (W, Q, R) score slab) scales with W,
    not n, and the return shape is (W,).

    ``oh_all``: optional pre-built :func:`_onehot_all` of ``data`` — full
    sweeps pass it so the child-independent one-hot is built once, not once
    per mapped child.  With ``pids`` the W candidate one-hot blocks are
    gathered out of it (a gather of a one-hot IS the one-hot of the gather,
    so this is exact), sparing the per-column rebuild on the restricted path.

    ``data_axis_name``: instance axis sharded over that mesh axis — each
    device contracts its m/d shard; one psum rebuilds the global joint
    counts before the (m-independent) BDeu reduction below.
    """
    cfg0, q0 = _slot_encode(data, arities, parent_mask)
    child_col = jnp.take(data, child, axis=1)
    cfg0c = jnp.clip(cfg0, 0, max_q - 1)
    if pids is None:
        data_c, ar_c = data, arities
    else:
        data_c = jnp.take(data, pids, axis=1)
        ar_c = jnp.take(arities, pids)
    w = data_c.shape[1]
    if counts_impl == "fused_pallas":
        from ..kernels.bdeu_sweep import sweep_counts, sweep_counts_restricted
        if pids is None:
            counts = sweep_counts(cfg0c, child_col, data,
                                  max_q=max_q, r_max=r_max,
                                  data_axis_name=data_axis_name)
        else:
            counts = sweep_counts_restricted(cfg0c, child_col, data, pids,
                                             max_q=max_q, r_max=r_max,
                                             data_axis_name=data_axis_name)
    else:
        if oh_all is not None and pids is not None:
            cols = (pids[:, None] * r_max
                    + jnp.arange(r_max, dtype=pids.dtype)[None, :]).reshape(-1)
            oh_all = jnp.take(oh_all, cols, axis=1)
        elif oh_all is None:
            oh_all = _onehot_all(data_c, r_max)
        counts = _sweep_counts_segment(cfg0c, child_col, oh_all, max_q, r_max)
        counts = _psum_counts(counts, data_axis_name)
    # (b, j0, x, a) -> per-candidate tables (x, (j0, a), b)
    c4 = counts.reshape(r_max, max_q, w, r_max)
    slab = c4.transpose(2, 1, 3, 0).reshape(w, max_q * r_max, r_max)
    q = q0.astype(jnp.float32) * ar_c.astype(jnp.float32)         # (w,)
    scores = _bdeu_from_counts(slab, q, arities[child], ess)
    log_q0 = jnp.sum(jnp.where(parent_mask,
                               jnp.log(arities.astype(jnp.float32)), 0.0))
    ok = (log_q0 + jnp.log(ar_c.astype(jnp.float32))
          ) <= jnp.log(jnp.float32(max_q)) + 1e-4
    return jnp.where(ok, scores, -jnp.inf)


def fused_delete_scores(
    data: Array,
    arities: Array,
    child: Array,
    parent_mask: Array,
    ess: float,
    max_q: int,
    r_max: int,
    counts_impl: str = "fused",
    pids: Array | None = None,
    data_axis_name: str | None = None,
) -> Array:
    """(n,) BDeu scores of ALL candidate families (Pa - {x}) for one child,
    from ONE family-table build.

    The BES delete sweep needs counts(Pa - {x}) for every parent x.  Every
    one of those tables is a *marginalization* of the current-family table:
    with the radix encoding of :func:`_slot_encode` (slot x has place value
    low_x = prod_{i>x} ar_i), row j0 decomposes as

        j0 = (hi * ar_x + d_x) * low_x + lo ,

    and summing the child-conditional counts over the digit d_x yields the
    table of Pa - {x} at rows hi * low_x + lo — an injective relabeling of
    the reduced configs, and BDeu depends only on the partition the codes
    induce.  So the whole delete column is ONE (max_q, r_max) table build
    (O(m), the same cost as the base score) plus an O(n * max_q * r_max)
    segment-sum with no data re-counting, replacing the loop engine's n
    per-candidate builds.

    Entry x holds score(child, Pa - {x}); at x not in Pa the marginalization
    is the identity (phantom arity-1 slot), so the entry equals the current
    family's score — the loop engine's no-op convention.  Candidates whose
    *reduced* family still overflows max_q are -inf, the same per-candidate
    guard convention as :func:`local_score_masked`.  When the current family
    itself overflows (q0 > max_q — possible only on unguarded init graphs,
    e.g. ring-fusion unions), the finite entries are clip-corrupted, but the
    *delta* against the (-inf) base reproduces the loop engine's +/-inf
    column exactly, so greedy trajectories still agree.

    ``pids``: optional (W,) candidate subset (ring E_i) — only the W
    marginalization maps are built and the return shape is (W,).

    With ``counts_impl="fused_pallas"`` the whole two-step dance — table
    build, HBM round-trip, jnp marginalization — collapses into ONE Pallas
    kernel (``kernels/bdeu_sweep.delete_scores``): the family table is
    accumulated in VMEM and each parent slot's marginal is reduced to its
    BDeu score in-register, so only the (n,)/(W,) score column ever reaches
    HBM.  Since a family has at most ``floor(log2(max_q))`` real (arity > 1)
    parents before it overflows the table bound (each multiplies q0 by at
    least 2), the kernel marginalizes that many slots; candidates that are
    not real parents read the base-family score off slot 0 (the identity
    marginalization, exactly this function's jnp no-op convention), and
    overflow-guarded families (q0 > max_q) only need the +/-inf *pattern*
    below, which the shared guard supplies.

    ``data_axis_name``: instance axis sharded over that mesh axis.  The VMEM
    kernel reduces counts to *scores* in-register and scores are not additive
    over shards, so under data sharding ``"fused_pallas"`` routes to the
    two-step path (table build via the psum-able ``contingency_counts``
    wrapper + jnp marginalization) — the kernel's own per-shard accumulation
    stays untouched.
    """
    n = data.shape[1]
    cfg0, q0 = _slot_encode(data, arities, parent_mask)
    child_col = jnp.take(data, child, axis=1)
    cfg0c = jnp.clip(cfg0, 0, max_q - 1)

    slot_ar_full = jnp.where(parent_mask, arities, 1).astype(jnp.int32)  # (n,)
    # place value of slot x under the _slot_encode scan: prod_{i > x} ar_i
    low_full = jnp.concatenate(
        [jnp.cumprod(slot_ar_full[::-1])[::-1][1:], jnp.ones(1, jnp.int32)])
    if pids is None:
        slot_ar, low = slot_ar_full, low_full
    else:
        slot_ar = jnp.take(slot_ar_full, pids)
        low = jnp.take(low_full, pids)
    w = slot_ar.shape[0]

    if counts_impl == "fused_pallas" and data_axis_name is None:
        from ..kernels.bdeu_sweep import delete_scores

        n_slots = max(1, min(n, max(int(max_q).bit_length() - 1, 1)))
        real = parent_mask & (arities > 1)               # identity slots skip
        rank = jnp.cumsum(real.astype(jnp.int32)) - 1
        # rank clamp only engages when q0 > max_q (2^(S+1) > max_q), where
        # finite values are garbage-by-convention and the guard below owns
        # the +/-inf pattern
        cand_slot_full = jnp.where(
            real, jnp.minimum(rank, n_slots - 1) + 1, 0).astype(jnp.int32)
        cand_slot = (cand_slot_full if pids is None
                     else jnp.take(cand_slot_full, pids))
        keys = jnp.where(real, jnp.arange(n, dtype=jnp.int32), n)
        slot_ids = jnp.sort(keys)[:n_slots]              # first S real parents
        live = slot_ids < n
        ids_c = jnp.minimum(slot_ids, n - 1)
        ar_s = jnp.where(live, jnp.take(slot_ar_full, ids_c), 1)
        low_s = jnp.where(live, jnp.take(low_full, ids_c), 1)
        qr = jnp.concatenate([
            q0.astype(jnp.float32)[None],
            (q0 // ar_s).astype(jnp.float32),
            arities[child].astype(jnp.float32)[None]])
        scores = delete_scores(cfg0c, child_col, cand_slot, ar_s, low_s, qr,
                               ess=ess, max_q=max_q, r_max=r_max)
    else:
        impl = single_impl(counts_impl)
        if impl == "onehot":
            counts0 = _dense_counts_onehot(cfg0c, child_col, r_max, max_q)
            counts0 = _psum_counts(counts0, data_axis_name)
        elif impl == "pallas":
            from ..kernels.bdeu_count import contingency_counts
            counts0 = contingency_counts(cfg0c, child_col,
                                         max_q=max_q, r_max=r_max,
                                         data_axis_name=data_axis_name)
        else:
            counts0 = _dense_counts_segment(cfg0c, child_col, r_max, max_q)
            counts0 = _psum_counts(counts0, data_axis_name)

        j0 = jnp.arange(max_q, dtype=jnp.int32)[None, :]             # (1, Q)
        low_c = low[:, None]
        hi = j0 // (low_c * slot_ar[:, None])
        lo = j0 % low_c
        mapped = hi * low_c + lo                                     # (w, Q)
        flat = (jnp.arange(w, dtype=jnp.int32)[:, None] * max_q + mapped)
        tiled = jnp.broadcast_to(counts0, (w,) + counts0.shape)
        slab = jax.ops.segment_sum(
            tiled.reshape(w * max_q, r_max), flat.reshape(-1),
            num_segments=w * max_q).reshape(w, max_q, r_max)

        q_del = (q0 // slot_ar).astype(jnp.float32)                  # (w,)
        scores = _bdeu_from_counts(slab, q_del, arities[child], ess)

    log_q0 = jnp.sum(jnp.where(parent_mask,
                               jnp.log(arities.astype(jnp.float32)), 0.0))
    ok = (log_q0 - jnp.log(slot_ar.astype(jnp.float32))
          ) <= jnp.log(jnp.float32(max_q)) + 1e-4
    return jnp.where(ok, scores, -jnp.inf)


def loop_insert_scores(
    data: Array,
    arities: Array,
    child: Array,
    parent_mask: Array,
    ess: float,
    max_q: int,
    r_max: int,
    counts_impl: str = "segment",
    pids: Array | None = None,
    data_axis_name: str | None = None,
) -> Array:
    """Loop-engine insert sweep with INCREMENTAL config encoding: scores of
    the candidate families (Pa + {x}) for one child, one contingency-table
    build per candidate.

    The parent-set radix code cfg0 is built once per child; each candidate
    extends it as ``cfg0 * r_x + X_x`` — O(m) per candidate instead of
    re-encoding all n slots.  BDeu depends only on the partition the codes
    induce (any injective relabeling gives identical counts), so the
    non-canonical code order is exact.

    This is THE loop-engine insert-column primitive: both the full (n, n)
    delta matrix (bdeu._deltas_impl) and the per-column/restricted sweeps
    (core/sweeps.sweep_column_body) call it, so full-n and pid-restricted
    programs see BITWISE-identical candidate scores — which the compiled
    ring's full-n tie-breaking argmax relies on (ges._masked_argmax_mapped).

    ``pids``: optional (W,) candidate subset — only those candidates are
    scored and the return shape is (W,).  Entries at x == child or x already
    in Pa are scored with the duplicated slot (garbage by convention, masked
    by callers); candidates whose extended family overflows max_q are -inf.

    ``data_axis_name``: instance axis sharded — each per-candidate table is
    psum'd over the mesh axis before its reduction (the vmap batches all W
    psums into one collective).
    """
    impl = single_impl(counts_impl)
    cfg0, q0 = _slot_encode(data, arities, parent_mask)
    child_col = jnp.take(data, child, axis=1)
    r = arities[child]
    log_q0 = jnp.sum(jnp.where(parent_mask,
                               jnp.log(arities.astype(jnp.float32)), 0.0))
    log_max = jnp.log(jnp.float32(max_q)) + 1e-4
    cand = (jnp.arange(data.shape[1], dtype=jnp.int32) if pids is None
            else pids)

    def per_parent(x):
        ar_x = arities[x]
        cfg = cfg0 * ar_x + jnp.take(data, x, axis=1)
        q = q0 * ar_x
        cfgc = jnp.clip(cfg, 0, max_q - 1)
        if impl == "onehot":
            counts = _dense_counts_onehot(cfgc, child_col, r_max, max_q)
            counts = _psum_counts(counts, data_axis_name)
        elif impl == "pallas":
            from ..kernels.bdeu_count import contingency_counts
            counts = contingency_counts(cfgc, child_col,
                                        max_q=max_q, r_max=r_max,
                                        data_axis_name=data_axis_name)
        else:
            counts = _dense_counts_segment(cfgc, child_col, r_max, max_q)
            counts = _psum_counts(counts, data_axis_name)
        score = _bdeu_from_counts(counts, q, r, ess)
        ok = (log_q0 + jnp.log(arities[x].astype(jnp.float32))) <= log_max
        return jnp.where(ok, score, -jnp.inf)

    return jax.vmap(per_parent)(cand)


def local_score_masked(
    data: Array,
    arities: Array,
    child: Array,
    parent_mask: Array,
    ess: float,
    max_q: int,
    r_max: int,
    counts_impl: str = "segment",
    data_axis_name: str | None = None,
) -> Array:
    """Jit-safe BDeu local score: child (scalar int), parent_mask (n,) bool.

    ``data_axis_name``: instance axis sharded — the family table is psum'd
    over that mesh axis before the (m-independent) reduction.
    """
    counts_impl = single_impl(counts_impl)
    cfg, q = _slot_encode(data, arities, parent_mask)
    child_col = jnp.take(data, child, axis=1)
    if counts_impl == "onehot":
        counts = _dense_counts_onehot(cfg, child_col, r_max, max_q)
        counts = _psum_counts(counts, data_axis_name)
    elif counts_impl == "pallas":
        from ..kernels.bdeu_count import contingency_counts
        counts = contingency_counts(
            jnp.clip(cfg, 0, max_q - 1), child_col, max_q=max_q, r_max=r_max,
            data_axis_name=data_axis_name)
    else:
        counts = _dense_counts_segment(cfg, child_col, r_max, max_q)
        counts = _psum_counts(counts, data_axis_name)
    r = arities[child]
    score = _bdeu_from_counts(counts, q, r, ess)
    # Dense-table overflow guard: if the true q exceeds the static table bound
    # the counts are invalid -> return -inf so greedy search never selects it.
    # (log-domain check; the int64 q itself can wrap for absurd parent sets.)
    log_q = jnp.sum(jnp.where(parent_mask, jnp.log(arities.astype(jnp.float32)), 0.0))
    ok = log_q <= jnp.log(jnp.float32(max_q)) + 1e-4
    return jnp.where(ok, score, -jnp.inf)


def family_scores_batch(
    data: Array,
    arities: Array,
    children: Array,
    parent_masks: Array,
    ess: float,
    max_q: int,
    r_max: int,
    counts_impl: str = "segment",
    data_axis_name: str | None = None,
) -> Array:
    """vmapped local scores for a batch of (child, parent_mask) families."""
    fn = lambda c, pm: local_score_masked(
        data, arities, c, pm, ess, max_q, r_max, counts_impl,
        data_axis_name=data_axis_name
    )
    return jax.vmap(fn)(children, parent_masks)


def graph_score_jax(
    data: Array,
    arities: Array,
    adj: Array,
    ess: float,
    max_q: int,
    r_max: int,
    counts_impl: str = "segment",
    data_axis_name: str | None = None,
) -> Array:
    """Total BDeu of a DAG (jit-safe): sum of all n local scores.

    Families whose true q exceeds ``max_q`` score -inf here (the compiled
    tables are max_q-wide by construction), whereas :func:`graph_score_np`
    reports the unguarded BDeu.  A fused init graph can hand GES such a
    family, and if BES never profits from deleting it the two engines then
    report different totals for the SAME final graph (the compiled one
    -inf) — score comparisons across engines must either avoid the guard
    (raise max_q) or compare finite entries only.  Worse, when the guard
    bites a base family but not its delete-reduced families, the compiled
    BES sees +inf deltas and deletes where the host engine (np-exact,
    unguarded local scores) sees the true negative delta and keeps —
    host-vs-compiled trajectory pins must therefore run with max_q above
    every family q the fused inits can produce."""
    n = adj.shape[0]
    children = jnp.arange(n, dtype=jnp.int32)
    masks = adj.astype(bool).T  # row y of masks = parents of y
    scores = family_scores_batch(
        data, arities, children, masks, ess, max_q, r_max, counts_impl,
        data_axis_name=data_axis_name
    )
    return scores.sum()


# ---------------------------------------------------------------------------
# Sweep-level primitives: all-candidate delta matrices (FES / BES)
# ---------------------------------------------------------------------------

def _deltas_impl(data, arities, adj, ess, max_q, r_max, counts_impl,
                 child_chunk, insert: bool,
                 axis_name=None, axis_size: int = 1,
                 data_axis_name=None):
    """Shared implementation of insert/delete delta matrices.

    The (n^2) candidate sweep would naively materialize (n, n, m) config
    intermediates — at paper scale (n~1000, m=5000) that is tens of GB.  We
    bound peak memory by mapping *sequentially* over chunks of children with
    ``lax.map`` (batched vmap inside each chunk):  peak = chunk * n * m.

    ``axis_name``: inside shard_map, split the child sweep across that mesh
    axis (the paper's "inner calculations in parallel" as scoring-TP): each
    device scores n/axis_size children, then an all-gather reassembles the
    (n, n) delta matrix.

    ``data_axis_name``: ORTHOGONAL second mesh axis sharding the instance
    (m) axis — each device contracts its m/d one-hot shard and the count
    tables are psum'd before every BDeu reduction.  Composes freely with the
    scoring-TP child split above (2-D mesh: children x instances).
    """
    n = adj.shape[0]
    children = jnp.arange(n, dtype=jnp.int32)
    base_masks = adj.astype(bool).T  # (n_child, n): row y = parents of y

    # Hoisted out of the per-child map: the data one-hot is child-independent.
    oh_all = (_onehot_all(data, r_max)
              if insert and counts_impl == "fused" else None)

    def per_child_insert_fused(args):
        """Fused insert sweep: ALL n candidate tables from one joint
        contraction (see fused_insert_scores) — the whole per-child loop
        below collapses to a single r_max-batched count build plus one
        vectorized (n, Q, R) -> (n,) BDeu reduction."""
        y, pm, b = args
        return fused_insert_scores(
            data, arities, y, pm, ess, max_q, r_max, counts_impl,
            oh_all=oh_all, data_axis_name=data_axis_name) - b

    def per_child_insert_loop(args):
        """Insert sweep via the ONE loop-engine primitive
        (:func:`loop_insert_scores`): incremental config encoding, one
        table build per candidate — shared with the per-column/restricted
        sweeps so full-n and restricted programs agree bitwise."""
        y, pm, b = args
        return loop_insert_scores(
            data, arities, y, pm, ess, max_q, r_max, counts_impl,
            data_axis_name=data_axis_name) - b

    def per_child_delete_fused(args):
        """Fused delete sweep: ONE family-table build per child; every
        candidate table is a marginalization of it over one parent slot
        (see fused_delete_scores) — zero re-counting for the whole column."""
        y, pm, b = args
        return fused_delete_scores(
            data, arities, y, pm, ess, max_q, r_max, counts_impl,
            data_axis_name=data_axis_name) - b

    def per_child_delete(args):
        y, pm, b = args

        def per_parent(x):
            new_pm = pm.at[x].set(False)
            return local_score_masked(
                data, arities, y, new_pm, ess, max_q, r_max, counts_impl,
                data_axis_name=data_axis_name
            )
        return jax.vmap(per_parent)(jnp.arange(n, dtype=jnp.int32)) - b

    if insert:
        per_child = (per_child_insert_fused if counts_impl in FUSED_IMPLS
                     else per_child_insert_loop)
    else:
        per_child = (per_child_delete_fused if counts_impl in FUSED_IMPLS
                     else per_child_delete)
    if counts_impl == "fused" and child_chunk is None:
        # A fused child sweep materializes a per-child slab — insert: the
        # (r_max * max_q, n * r_max) joint counts; delete: the (n, max_q,
        # r_max) marginalization stack.  Map children sequentially so that
        # slab exists for one child at a time instead of vmapping it n-wide
        # (n^2-scale peak memory).  ("fused_pallas" is exempt: pallas_call
        # in interpret mode cannot trace lax.map's zero-size remainder batch
        # on jax 0.4.x — callers bound its memory with an explicit
        # child_chunk.)
        child_chunk = 1

    def base_for(ch, masks):
        return family_scores_batch(
            data, arities, ch, masks, ess, max_q, r_max, counts_impl,
            data_axis_name=data_axis_name)

    if axis_name is not None:
        per = -(-n // axis_size)                    # children per device
        i = jax.lax.axis_index(axis_name)
        ids = jnp.clip(i * per + jnp.arange(per), 0, n - 1).astype(jnp.int32)
        masks_l = base_masks[ids]
        base_l = base_for(ids, masks_l)
        scores_l = jax.lax.map(per_child, (ids, masks_l, base_l),
                               batch_size=min(child_chunk or per, per))
        scores = jax.lax.all_gather(scores_l, axis_name, axis=0,
                                    tiled=True)[:n]     # (y, x)
        return scores.T

    base = base_for(children, base_masks)
    if child_chunk is None or child_chunk >= n:
        scores_xy = jax.vmap(per_child)((children, base_masks, base))
    else:
        scores_xy = jax.lax.map(
            per_child, (children, base_masks, base), batch_size=child_chunk
        )
    return scores_xy.T


def insert_deltas(
    data: Array,
    arities: Array,
    adj: Array,
    ess: float,
    max_q: int,
    r_max: int,
    counts_impl: str = "segment",
    child_chunk: int | None = None,
    axis_name=None,
    axis_size: int = 1,
    data_axis_name=None,
) -> Array:
    """Delta matrix D[x, y] = score(y, Pa_y + {x}) - score(y, Pa_y) for all pairs.

    Invalid candidates (x == y, existing edges, parent-set overflow w.r.t.
    max_q) are NOT masked here — callers apply masks (allowed-edge set E_i,
    acyclicity, cGES-L limits).  Shape (n, n), jit-safe.
    """
    return _deltas_impl(data, arities, adj, ess, max_q, r_max, counts_impl,
                        child_chunk, insert=True,
                        axis_name=axis_name, axis_size=axis_size,
                        data_axis_name=data_axis_name)


def delete_deltas(
    data: Array,
    arities: Array,
    adj: Array,
    ess: float,
    max_q: int,
    r_max: int,
    counts_impl: str = "segment",
    child_chunk: int | None = None,
    axis_name=None,
    axis_size: int = 1,
    data_axis_name=None,
) -> Array:
    """Delta matrix D[x, y] = score(y, Pa_y - {x}) - score(y, Pa_y).

    Only meaningful where adj[x, y] == 1; other entries are garbage and must
    be masked by the caller.
    """
    return _deltas_impl(data, arities, adj, ess, max_q, r_max, counts_impl,
                        child_chunk, insert=False,
                        axis_name=axis_name, axis_size=axis_size,
                        data_axis_name=data_axis_name)


def pairwise_similarity_jax(
    data: Array, arities: Array, ess: float, r_max: int
) -> Array:
    """Jit-safe Eq. (4) similarity matrix (for edge partitioning)."""
    n = data.shape[1]
    empty = jnp.zeros((n, n), dtype=jnp.int8)
    d = insert_deltas(data, arities, empty, ess, max_q=r_max, r_max=r_max)
    s = 0.5 * (d + d.T)
    return s - jnp.diag(jnp.diag(s))


def pairwise_similarity_fast(
    data: np.ndarray, arities: np.ndarray, ess: float = 10.0
) -> np.ndarray:
    """All-pairs Eq. (4) similarity from ONE contingency matmul.

    Every 2-way table N[i,a,j,b] = #(X_i=a AND X_j=b) is a block of
    OH(data)^T @ OH(data) with OH the (m, n*r_max) padded one-hot — the same
    MXU-native contraction as the ``bdeu_count`` Pallas kernel, batched over
    all n^2 pairs at once.  Replaces n^2 independent per-pair scans:
    flops = m*(n*r_max)^2 (one matmul) instead of n^2 scoring dispatches.

    Exactness: padded states/rows have zero counts and their BDeu terms
    cancel (lgamma(0+a) - lgamma(a) = 0), so the padded algebra is exact.
    """
    m, n = data.shape
    r_max = int(arities.max())
    # one-hot (m, n*r_max); column i*r_max+a  <->  (X_i == a)
    oh = np.zeros((m, n * r_max), dtype=np.float32)
    cols = (np.arange(n)[None, :] * r_max + data).astype(np.int64)
    np.put_along_axis(oh.reshape(m, -1), cols, 1.0, axis=1)
    counts = (oh.T @ oh).reshape(n, r_max, n, r_max).astype(np.float64)

    r = arities.astype(np.float64)                       # (n,)
    # child i given parent j:  q = r_j, r = r_i
    q_ji = r[None, :]                                    # Q[i, j] = r_j
    r_ii = r[:, None]
    a_j = ess / q_ji                                     # (n, n)
    a_jk = ess / (q_ji * r_ii)
    # N[j_state, i_state] for (child i, parent j) is counts[j, :, i, :]
    njk = counts.transpose(2, 0, 1, 3)                   # (i, j, a_j, b_i)
    nj = njk.sum(axis=3)                                 # (i, j, a_j)
    term_j = (lgamma_np(a_j)[..., None] - lgamma_np(nj + a_j[..., None]))
    term_jk = (lgamma_np(njk + a_jk[..., None, None])
               - lgamma_np(np.broadcast_to(a_jk[..., None, None], njk.shape)))
    with_parent = term_j.sum(axis=2) + term_jk.sum(axis=(2, 3))  # (i, j)

    # base: child i with no parent (q = 1)
    ni = np.stack([counts[i, :, i, :].diagonal() for i in range(n)])  # (n, r)
    b_j = ess
    b_jk = ess / r
    base = (lgamma_np(np.full(n, b_j)) - lgamma_np(ni.sum(1) + b_j)
            + (lgamma_np(ni + b_jk[:, None])
               - lgamma_np(np.broadcast_to(b_jk[:, None], ni.shape))).sum(1))

    d = with_parent - base[:, None]                      # s(X_i <- X_j)
    s = 0.5 * (d + d.T)
    np.fill_diagonal(s, 0.0)
    return s
