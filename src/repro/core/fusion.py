"""Bayesian-network fusion (Puerta et al. 2021) — the ring's merge operator.

``fuse`` combines DAGs G_1..G_j into a single DAG that preserves every
conditional *dependence* of each input (its independencies are a subset of
each input's): each G_i is transformed into a sigma-consistent DAG G_i^sigma
via covered-edge reversals (which keep Markov equivalence) plus edge
additions (which only remove independencies), and the results are unioned.
All edges of every G_i^sigma respect the common ordering sigma, so the union
is guaranteed to be a DAG.

The ordering is produced by a greedy heuristic in the spirit of the paper's
GHO: build sigma from the back by repeatedly picking the node that is
cheapest to convert into a sink across all input DAGs (cost = number of
out-edges inside the remaining subgraph; the first-order term of the full
GHO cost — the covering additions it ignores are second-order).

Sink conversion (the core subroutine) processes nodes in reverse sigma
order.  To sink ``v`` inside the remaining subgraph S we repeatedly pick the
out-neighbour ``w`` of smallest *depth* (longest-path layer) in S: the
minimal-depth choice guarantees no alternative v~>w path exists, so covering
the edge (adding Pa(v)\\Pa(w) into w and Pa(w)\\{v}\\Pa(v) into v) followed by
reversal keeps the graph acyclic.  Invariant maintained: processed nodes
never have out-edges into unprocessed nodes, hence parent sets stay inside S
and the final graph is sigma-consistent.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


def _subgraph_depth(adj: np.ndarray, in_s: np.ndarray) -> np.ndarray:
    """Longest-path layer of each node within the induced subgraph on ``in_s``.

    depth[v] = 0 for sources; nodes outside S get -1.
    """
    n = adj.shape[0]
    sub = adj.astype(bool) & in_s[:, None] & in_s[None, :]
    depth = np.where(in_s, 0, -1).astype(np.int64)
    for _ in range(n):
        # depth[w] = 1 + max depth of parents (within S)
        parent_d = np.where(sub, depth[:, None], -1)
        new = np.where(in_s, np.maximum(depth, parent_d.max(axis=0) + 1), -1)
        if np.array_equal(new, depth):
            break
        depth = new
    return depth


def sigma_consistent(adj: np.ndarray, sigma: Sequence[int]) -> np.ndarray:
    """Transform a DAG so every edge x->y satisfies rank(x) < rank(y).

    Preserves all conditional dependencies of the input (adds edges /
    reverses covered edges only).  Returns a new adjacency matrix.
    """
    adj = adj.astype(bool).copy()
    n = adj.shape[0]
    rank = np.empty(n, dtype=np.int64)
    for pos, v in enumerate(sigma):
        rank[v] = pos

    processed = np.zeros(n, dtype=bool)
    for v in sorted(range(n), key=lambda u: -rank[u]):
        in_s = ~processed  # v included
        while True:
            out_nbrs = np.flatnonzero(adj[v] & in_s)
            if out_nbrs.size == 0:
                break
            depth = _subgraph_depth(adj, in_s)
            w = int(out_nbrs[np.argmin(depth[out_nbrs])])
            # cover the edge v->w
            pa_v = adj[:, v].copy()
            pa_w = adj[:, w].copy()
            add_to_w = pa_v & ~pa_w
            add_to_w[w] = False
            add_to_w[v] = False
            adj[:, w] |= add_to_w
            add_to_v = pa_w & ~pa_v
            add_to_v[v] = False
            add_to_v[w] = False
            adj[:, v] |= add_to_v
            # reverse
            adj[v, w] = False
            adj[w, v] = True
        processed[v] = True
    return adj


def gho_order(adjs: Sequence[np.ndarray]) -> np.ndarray:
    """Greedy heuristic ordering: cheapest-sink-first, built back-to-front."""
    n = adjs[0].shape[0]
    remaining = np.ones(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    stack = [a.astype(bool) for a in adjs]
    for pos in range(n - 1, -1, -1):
        # cost(v) = total out-degree of v within the remaining subgraph
        costs = np.full(n, np.inf)
        idx = np.flatnonzero(remaining)
        sub_cost = np.zeros(n, dtype=np.int64)
        for a in stack:
            sub_cost += (a & remaining[None, :]).sum(axis=1)
        costs[idx] = sub_cost[idx]
        v = int(np.argmin(costs))
        order[pos] = v
        remaining[v] = False
    return order


def fuse(
    adjs: Sequence[np.ndarray], sigma: Optional[Sequence[int]] = None
) -> np.ndarray:
    """Fusion = union of sigma-consistent transforms (edge union of the paper).

    With ``sigma=None`` the GHO heuristic picks the ordering.  The result is a
    DAG whose independencies are contained in every input's.
    """
    adjs = [a.astype(bool) for a in adjs]
    if sigma is None:
        sigma = gho_order(adjs)
    out = np.zeros_like(adjs[0])
    for a in adjs:
        out |= sigma_consistent(a, sigma)
    return out


def fusion_edge_union(g_own: np.ndarray, g_pred: np.ndarray) -> np.ndarray:
    """Algorithm 1, line 9:  Fusion.edgeUnion(G_i, G_{i-1})  — pairwise fusion."""
    if not g_own.any():
        return g_pred.astype(bool).copy()
    if not g_pred.any():
        return g_own.astype(bool).copy()
    return fuse([g_own, g_pred])
