"""Bayesian-network fusion (Puerta et al. 2021) — the ring's merge operator,
as ONE engine shared by the host driver and the compiled ring.

``fuse`` combines DAGs G_1..G_j into a single DAG that preserves every
conditional *dependence* of each input (its independencies are a subset of
each input's): each G_i is transformed into a sigma-consistent DAG G_i^sigma
via covered-edge reversals (which keep Markov equivalence) plus edge
additions (which only remove independencies), and the results are unioned.
All edges of every G_i^sigma respect the common ordering sigma, so the union
is guaranteed to be a DAG.

The ordering is produced by a greedy heuristic in the spirit of the paper's
GHO: build sigma from the back by repeatedly picking the node that is
cheapest to convert into a sink across all input DAGs (cost = number of
out-edges inside the remaining subgraph; the first-order term of the full
GHO cost — the covering additions it ignores are second-order).  The cost
vector is maintained *incrementally*: sinking node s removes the edges
``u -> s`` from every remaining subgraph, so each position subtracts the
stacked adjacency column ``total[:, s]`` instead of re-summing all j (n, n)
masks.

Sink conversion (the core subroutine) processes nodes in reverse sigma
order.  To sink ``v`` inside the remaining subgraph S we repeatedly pick the
out-neighbour ``w`` of smallest *depth* (longest-path layer) in S: the
minimal-depth choice guarantees no alternative v~>w path exists, so covering
the edge (adding Pa(v)\\Pa(w) into w and Pa(w)\\{v}\\Pa(v) into v) followed by
reversal keeps the graph acyclic.  Invariant maintained: processed nodes
never have out-edges into unprocessed nodes, hence parent sets stay inside S
and the final graph is sigma-consistent.

Depth is *maintained*, not recomputed: one longest-path-layer vector lives
across the whole transform.  A covered reversal of v->w only changes the
in-edges of v and w (after covering, Pa(w) = Pa(v) u {v}; neither v nor w
can be an ancestor of the shared parents without creating a cycle or an
alternative v~>w path), so the perturbation re-settles by iterating the pure
Bellman update  depth[u] = max(0, max_{p in Pa(u) & S} depth[p] + 1)  from
the previous depths until stationary.  The update's fixed point on a DAG is
unique (induction over a topological order), and any seed washes out after
longest-path-many steps, so the early-exit iteration is exact while touching
only as many rounds as the perturbation actually propagates — instead of the
full O(n)-sweep recompute per reversal the pre-refactor engines paid.
Completing a node removes a *sink* of S, which shifts nobody's layer, so the
shrink is one masked write.

Engines (adjacency-for-adjacency identical — same GHO ranks, same
lowest-index tie-breaks, same covered-reversal sequence):

* ``engine="host"`` — numpy, the checkpointable cGES driver path.
* ``engine="jit"``  — the traceable engine below (``fuse_trace``), also used
  verbatim inside the shard_map ring (core/ring.py imports it); the j
  per-input sigma transforms share one GHO rank vector and are batched with
  ``vmap`` over the stacked DAGs, whose lockstep while_loops give every
  reversal a shared early-exit bound (the loop runs max-over-inputs trips,
  each depth re-settle is capped at |S| + 1 Bellman steps on the shrinking
  remaining subgraph).

``fusion_edge_union`` / ``fuse`` default their engine from the
``REPRO_FUSION_ENGINE`` env var (mirroring ``REPRO_COUNTS_IMPL``); unknown
names fail loudly via :func:`check_fusion_engine`.
"""
from __future__ import annotations

import os
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

Array = jax.Array

FUSION_ENGINES = ("host", "jit")


def check_fusion_engine(engine: str) -> None:
    """Fail loudly on unknown engines (typos must not silently fall back)."""
    if engine not in FUSION_ENGINES:
        raise ValueError(
            f"unknown fusion engine {engine!r}: expected one of "
            f"{FUSION_ENGINES} (set via cges(fusion_engine=...), the "
            f"--fusion-engine flag, or the REPRO_FUSION_ENGINE env var)")


def resolve_fusion_engine(engine: Optional[str] = None) -> str:
    """``None`` -> the REPRO_FUSION_ENGINE env default (else "host")."""
    if engine is None:
        engine = os.environ.get("REPRO_FUSION_ENGINE", "host")
    check_fusion_engine(engine)
    return engine


# ---------------------------------------------------------------------------
# Host engine (numpy)
# ---------------------------------------------------------------------------

def _settle_depth_np(adj: np.ndarray, in_s: np.ndarray,
                     depth: np.ndarray) -> np.ndarray:
    """Iterate the pure Bellman depth update from ``depth`` until stationary.

    The fixed point is the longest-path layer of the induced subgraph on
    ``in_s`` (unique; any seed washes out after longest-path-many steps), so
    seeding with the pre-mutation depths re-settles in as few rounds as the
    perturbation propagates.  Nodes outside S stay -1.  The n + 1 cap never
    binds on a DAG (layers are < |S|); it keeps garbage inputs containing a
    cycle finite instead of looping forever.
    """
    sub = adj.astype(bool) & in_s[:, None] & in_s[None, :]
    for _ in range(adj.shape[0] + 1):
        parent_d = np.where(sub, depth[:, None], -1)
        new = np.where(in_s, np.maximum(parent_d.max(axis=0) + 1, 0), -1)
        if np.array_equal(new, depth):
            break
        depth = new
    return depth


def _subgraph_depth(adj: np.ndarray, in_s: np.ndarray) -> np.ndarray:
    """Longest-path layer of each node within the induced subgraph on ``in_s``.

    depth[v] = 0 for sources; nodes outside S get -1.  (From-scratch oracle;
    the transforms below maintain this vector incrementally.)
    """
    return _settle_depth_np(adj, in_s, np.where(in_s, 0, -1).astype(np.int64))


def sigma_consistent(adj: np.ndarray, sigma: Sequence[int]) -> np.ndarray:
    """Transform a DAG so every edge x->y satisfies rank(x) < rank(y).

    Preserves all conditional dependencies of the input (adds edges /
    reverses covered edges only).  Returns a new adjacency matrix.
    """
    adj = adj.astype(bool).copy()
    n = adj.shape[0]
    rank = np.empty(n, dtype=np.int64)
    for pos, v in enumerate(sigma):
        rank[v] = pos

    in_s = np.ones(n, dtype=bool)
    depth = _settle_depth_np(adj, in_s, np.zeros(n, dtype=np.int64))
    for v in sorted(range(n), key=lambda u: -rank[u]):
        while True:
            out_nbrs = np.flatnonzero(adj[v] & in_s)
            if out_nbrs.size == 0:
                break
            w = int(out_nbrs[np.argmin(depth[out_nbrs])])
            # cover the edge v->w
            pa_v = adj[:, v].copy()
            pa_w = adj[:, w].copy()
            add_to_w = pa_v & ~pa_w
            add_to_w[w] = False
            add_to_w[v] = False
            adj[:, w] |= add_to_w
            add_to_v = pa_w & ~pa_v
            add_to_v[v] = False
            add_to_v[w] = False
            adj[:, v] |= add_to_v
            # reverse
            adj[v, w] = False
            adj[w, v] = True
            # only the in-edges of v and w changed: re-settle from old depths
            depth = _settle_depth_np(adj, in_s, depth)
        # v is now a sink within S: dropping it shifts no other node's layer
        in_s[v] = False
        depth[v] = -1
    return adj


def gho_order(adjs: Sequence[np.ndarray]) -> np.ndarray:
    """Greedy heuristic ordering: cheapest-sink-first, built back-to-front.

    cost(v) = total out-degree of v within the remaining subgraph, summed
    over the input DAGs — maintained incrementally: sinking node s subtracts
    the stacked column ``total[:, s]`` (the u -> s edges that left every
    remaining subgraph) instead of re-summing all (n, n) masks per position.
    Ties break to the lowest node index, matching the traceable engine.
    """
    n = adjs[0].shape[0]
    remaining = np.ones(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    total = np.zeros((n, n), dtype=np.int64)
    for a in adjs:
        total += a.astype(bool)
    sub_cost = total.sum(axis=1)
    for pos in range(n - 1, -1, -1):
        costs = np.where(remaining, sub_cost.astype(np.float64), np.inf)
        v = int(np.argmin(costs))
        order[pos] = v
        remaining[v] = False
        sub_cost = sub_cost - total[:, v]
    return order


# ---------------------------------------------------------------------------
# Traceable engine (used verbatim inside the shard_map ring)
# ---------------------------------------------------------------------------

def _depth_step(adj: Array, in_s: Array, depth: Array) -> Array:
    """One pure Bellman update of the longest-path layers within ``in_s``."""
    sub = adj.astype(bool) & in_s[:, None] & in_s[None, :]
    parent_d = jnp.where(sub, depth[:, None], jnp.int32(-1))
    new = jnp.maximum(parent_d.max(axis=0) + 1, 0)
    return jnp.where(in_s, new, -1).astype(jnp.int32)


def _settle_depth(adj: Array, in_s: Array, depth: Array, bound) -> Array:
    """Iterate :func:`_depth_step` from ``depth`` until stationary.

    ``bound`` caps the trip count: layers within S are < |S|, so any seed is
    stationary after at most |S| + 1 steps — callers pass the shrinking
    |S| + 1, which shared-early-exits the loop (under vmap all stacked
    inputs ride the same loop and stop when every lane has settled).
    """
    def cond(c):
        prev, cur, it = c
        return jnp.any(prev != cur) & (it < bound)

    def body(c):
        _, cur, it = c
        return cur, _depth_step(adj, in_s, cur), it + 1

    _, settled, _ = jax.lax.while_loop(
        cond, body, (depth, _depth_step(adj, in_s, depth), jnp.int32(0)))
    return settled


def gho_rank_trace(adjs: Array) -> Array:
    """Greedy cheapest-sink ranks over stacked DAGs (j, n, n) -> (n,) int32
    (rank[v] = position of v in sigma).  Incremental cost maintenance, same
    lowest-index tie-break as the host engine."""
    n = adjs.shape[-1]
    total = adjs.astype(jnp.int32).sum(axis=0)        # (n, n) stacked edges

    def body(step, carry):
        rank, remaining, cost = carry
        c = jnp.where(remaining, cost, jnp.iinfo(jnp.int32).max)
        v = jnp.argmin(c)  # deterministic: lowest index on ties
        pos = n - 1 - step
        return (rank.at[v].set(pos), remaining.at[v].set(False),
                cost - total[:, v])

    rank0 = jnp.zeros(n, dtype=jnp.int32)
    remaining0 = jnp.ones(n, dtype=bool)
    rank, _, _ = jax.lax.fori_loop(0, n, body, (rank0, remaining0,
                                                total.sum(axis=1)))
    return rank


def sigma_consistent_trace(adj: Array, rank: Array) -> Array:
    """Traceable sink-conversion transform (see :func:`sigma_consistent`).

    Maintains ONE depth vector across all reversals of all nodes: each
    covered reversal re-settles it from the previous values (`_settle_depth`
    with the shrinking |S| + 1 bound) instead of recomputing all n layers,
    and completing a node — a sink of S by construction — is a single masked
    write.  Designed to be vmapped over stacked DAGs sharing one rank.
    """
    n = adj.shape[0]
    adj = adj.astype(jnp.int8)
    rank = rank.astype(jnp.int32)
    order = jnp.argsort(-rank)  # processing order: highest rank first
    idx = jnp.arange(n)
    int_max = jnp.iinfo(jnp.int32).max

    depth0 = _settle_depth(adj, jnp.ones(n, dtype=bool),
                           jnp.zeros(n, jnp.int32), jnp.int32(n + 1))

    def process_node(step, carry):
        adj, depth = carry
        v = order[step]
        # unprocessed = nodes with rank <= rank[v] (v included)
        in_s = rank <= rank[v]
        bound = jnp.int32(n - step + 1)               # |S| + 1

        def cond(c):
            adj, _, it = c
            out = jnp.take(adj, v, axis=0).astype(bool) & in_s
            # each reversal removes one out-edge of v from S, so the n cap
            # never binds — it is a shared safety bound for the vmapped loop
            return out.any() & (it < n)

        def body(c):
            adj, depth, it = c
            out = jnp.take(adj, v, axis=0).astype(bool) & in_s
            w = jnp.argmin(jnp.where(out, depth, int_max))
            pa_v = jnp.take(adj, v, axis=1).astype(bool)
            pa_w = jnp.take(adj, w, axis=1).astype(bool)
            add_to_w = pa_v & ~pa_w & (idx != w) & (idx != v)
            add_to_v = pa_w & ~pa_v & (idx != v) & (idx != w)
            adj = adj.at[:, w].set((pa_w | add_to_w).astype(adj.dtype))
            pa_v2 = jnp.take(adj, v, axis=1).astype(bool)
            adj = adj.at[:, v].set((pa_v2 | add_to_v).astype(adj.dtype))
            adj = adj.at[v, w].set(0)
            adj = adj.at[w, v].set(1)
            # only the in-edges of v and w changed: re-settle, don't recompute
            depth = _settle_depth(adj, in_s, depth, bound)
            return adj, depth, it + 1

        adj, depth, _ = jax.lax.while_loop(cond, body,
                                           (adj, depth, jnp.int32(0)))
        # v is now a sink within S: dropping it shifts no other node's layer
        return adj, depth.at[v].set(-1)

    adj, _ = jax.lax.fori_loop(0, n, process_node, (adj, depth0))
    return adj


def fuse_stack_trace(adjs: Array, rank: Optional[Array] = None) -> Array:
    """Traceable j-ary fusion core: one GHO rank over the stacked (j, n, n)
    DAGs, the j sigma transforms batched with vmap (they are independent
    given the shared rank), union.  No empty-input guard — mirrors the host
    :func:`fuse` exactly; Algorithm 1's skip lives in the pairwise wrappers.
    """
    adjs = adjs.astype(jnp.int8)
    if rank is None:
        rank = gho_rank_trace(adjs)
    transformed = jax.vmap(sigma_consistent_trace, in_axes=(0, None))(adjs,
                                                                      rank)
    return transformed.astype(bool).any(axis=0).astype(jnp.int8)


def fuse_trace(g_own: Array, g_pred: Array) -> Array:
    """Traceable pairwise fusion — the ring's merge operator (core/ring.py
    calls this verbatim inside shard_map).  Algorithm 1 skips fusion when
    either side is empty."""
    a = g_own.astype(jnp.int8)
    b = g_pred.astype(jnp.int8)
    fused = fuse_stack_trace(jnp.stack([a, b]))
    own_empty = ~a.astype(bool).any()
    pred_empty = ~b.astype(bool).any()
    fused = jnp.where(own_empty, b, fused)
    fused = jnp.where(pred_empty & ~own_empty, a, fused)
    return fused


# Compat names (pre-unification callers imported these via core/ring.py).
fuse_jit = fuse_trace
sigma_consistent_jit = sigma_consistent_trace


def gho_order_jit(adj_a: Array, adj_b: Array) -> Array:
    """Pairwise compat wrapper around :func:`gho_rank_trace`."""
    return gho_rank_trace(jnp.stack([adj_a.astype(jnp.int8),
                                     adj_b.astype(jnp.int8)]))


_fuse_stack_jitted = jax.jit(fuse_stack_trace)


# ---------------------------------------------------------------------------
# Engine-dispatching host API
# ---------------------------------------------------------------------------

def fuse(
    adjs: Sequence[np.ndarray],
    sigma: Optional[Sequence[int]] = None,
    engine: Optional[str] = None,
) -> np.ndarray:
    """Fusion = union of sigma-consistent transforms (edge union of the paper).

    With ``sigma=None`` the GHO heuristic picks the ordering.  The result is a
    DAG whose independencies are contained in every input's.  ``engine``
    picks the host (numpy) or traceable (jit) implementation — identical
    adjacency-for-adjacency; ``None`` defaults from REPRO_FUSION_ENGINE.
    """
    engine = resolve_fusion_engine(engine)
    adjs = [np.asarray(a).astype(bool) for a in adjs]
    if engine == "jit":
        stacked = jnp.asarray(np.stack(adjs).astype(np.int8))
        if sigma is None:
            out = _fuse_stack_jitted(stacked)
        else:
            rank = np.empty(len(sigma), dtype=np.int32)
            rank[np.asarray(sigma, dtype=np.int64)] = np.arange(
                len(sigma), dtype=np.int32)
            out = _fuse_stack_jitted(stacked, jnp.asarray(rank))
        return np.asarray(out).astype(bool)
    if sigma is None:
        sigma = gho_order(adjs)
    out = np.zeros_like(adjs[0])
    for a in adjs:
        out |= sigma_consistent(a, sigma)
    return out


def fusion_edge_union(
    g_own: np.ndarray, g_pred: np.ndarray, engine: Optional[str] = None
) -> np.ndarray:
    """Algorithm 1, line 9:  Fusion.edgeUnion(G_i, G_{i-1})  — pairwise fusion.

    Fusion is skipped when either side is empty (same guard the compiled
    ring's :func:`fuse_trace` applies with jnp.where).
    """
    engine = resolve_fusion_engine(engine)
    if not g_own.any():
        return g_pred.astype(bool).copy()
    if not g_pred.any():
        return g_own.astype(bool).copy()
    return fuse([g_own, g_pred], engine=engine)
