"""The unified sweep engine — ONE API for every GES/ring/cGES delta rescoring.

Every rescoring step of the paper's algorithms is a *sweep*: score all
candidate single-edge changes toward one child (a column) or all children
(a matrix), as a batched delta against the current graph.  This module is the
single layer every driver goes through; the per-engine primitives live in
:mod:`repro.core.bdeu` and :mod:`repro.kernels.bdeu_sweep`.

Mapping of sweep kinds onto the paper (arXiv 2409.13314, Algorithm 1 / §2.2):

* ``kind="insert"`` — the **FES** candidate sweep: deltas for adding x -> y.
  This is the "evaluate all allowed arcs in parallel" step each ring process
  performs per round, and the whole of GES's forward stage.
* ``kind="delete"`` — the **BES** candidate sweep: deltas for removing
  x -> y.  Runs inside every ring process's constrained GES and in the final
  unrestricted fine-tuning pass.
* ``pids`` (candidate subset) — the paper's **restricted edge sets E_i**: a
  ring process with |E_i| ~ n/k allowed parents per column sweeps only those
  W candidates, which is the mechanism that makes the ring cheaper than
  monolithic GES.  ``pids=None`` sweeps all n candidates (the fine-tune /
  plain-GES case).
* ``pid_table`` (static (n, W) candidate table, one ``pids`` row per child —
  see :func:`repro.core.partition.pid_table_from_allowed`) — the whole-round
  restricted sweep: a masked **(W, n)** delta matrix whose entry [w, y] is
  the delta for toggling ``pid_table[y, w] -> y``.  This is what the
  compiled ``ges_jit``/shard_map-ring path initializes FES/BES from, so the
  fully-compiled ring pays W-wide matrix sweeps end-to-end instead of
  sweeping full-n and masking afterwards.  Rows are self-padded (pad slots
  hold ``y``), and padding comes back -inf like any other illegal toggle.

Backends (selected by ``counts_impl``):

* ``"segment" | "onehot" | "pallas"`` — the **loop** engine: one contingency
  table build per candidate (vmapped).
* ``"fused"`` — jnp segment-sum realizations of the fused sweeps: insert
  columns from ONE joint child-value-batched contraction
  (:func:`bdeu.fused_insert_scores`), delete columns from ONE family-table
  build marginalized over each parent slot
  (:func:`bdeu.fused_delete_scores`).
* ``"fused_pallas"`` — same math with the tiled Pallas kernels
  (``kernels/bdeu_sweep``): insert columns run the joint one-hot
  contraction kernel, and delete columns run the **VMEM-resident** delete
  kernel (``delete_scores``) — the one current-family (max_q, r) table is
  accumulated in VMEM scratch and each parent slot's marginal is reduced
  straight to its BDeu score in-kernel, so the table never round-trips
  through HBM and only the (n,)/(W,) score column is written back
  (interpret mode on CPU, compiled on TPU; identical masking/guard
  conventions to the jnp engines).

Convention (stronger than the raw bdeu primitives): returned columns and
matrices are **masked** — entries that are not a legal toggle (self-loops,
inserting an existing edge, deleting a missing edge, candidates outside a
``pids`` subset's real extent via self-padding) are -inf under EVERY backend,
so callers cannot select them by forgetting a mask and all backends agree
entry-for-entry.  Graph-level validity (acyclicity, max_parents, allowed-edge
sets E_i, q-guard for inserts) remains the caller's mask, as before.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import bdeu

Array = jax.Array
NEG_INF = -jnp.inf

KINDS = ("insert", "delete")


def _check_kind(kind: str) -> bool:
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    return kind == "insert"


def _check_pids(pids, n: int, name: str = "pids") -> Array:
    """Validate a candidate-id vector/table against the variable count n.

    Shape problems (wrong rank, more candidates than variables) and
    out-of-range ids raise ``ValueError`` immediately instead of flowing into
    the gather as silent wrong shapes / clamped indices.  Value checks are
    skipped for traced arrays (inside jit the caller's ids are assumed
    pre-validated — the public ``sweep`` entry point sees concrete arrays).
    """
    pids = jnp.asarray(pids)
    if not jnp.issubdtype(pids.dtype, jnp.integer):
        raise ValueError(f"{name} must be integer-typed, got {pids.dtype}")
    width = pids.shape[-1]
    if width > n:
        raise ValueError(
            f"{name} has {width} candidates per column but only n = {n} "
            f"variables exist — pad with the child id (self-loop), not by "
            f"exceeding n")
    if not isinstance(pids, jax.core.Tracer) and pids.size:
        vals = np.asarray(pids)
        if vals.min() < 0 or vals.max() >= n:
            bad = vals[(vals < 0) | (vals >= n)]
            raise ValueError(
                f"{name} contains out-of-range variable ids {bad[:8]} "
                f"(valid range [0, {n}))")
    return pids


# ---------------------------------------------------------------------------
# Column sweeps (incremental rescoring: only column y changed)
# ---------------------------------------------------------------------------

def sweep_column_body(data, arities, adj, y, pids, ess, max_q, r_max,
                      counts_impl, kind):
    """Traceable masked delta column — callable from inside jit/shard_map.

    Returns (n,) deltas for toggling x -> y over all candidates x, or (W,)
    over the ``pids`` subset.  See the module docstring for the masking
    convention; with a fused ``counts_impl`` the whole column costs one joint
    contraction (insert) or one family-table build (delete) instead of one
    table build per candidate.
    """
    insert = _check_kind(kind)
    n = adj.shape[0]
    pm = adj.astype(bool)[:, y]
    base = bdeu.local_score_masked(
        data, arities, y, pm, ess, max_q, r_max, counts_impl)
    cand = jnp.arange(n, dtype=jnp.int32) if pids is None else pids

    if counts_impl in bdeu.FUSED_IMPLS:
        fn = bdeu.fused_insert_scores if insert else bdeu.fused_delete_scores
        deltas = fn(data, arities, y, pm, ess, max_q, r_max, counts_impl,
                    pids=pids) - base
    elif insert:
        # The ONE loop-engine insert primitive (incremental config
        # encoding) — shared with bdeu._deltas_impl's full matrix, so a
        # restricted column is bitwise equal to the matching full-n
        # matrix entries and full-n tie-breaks transfer exactly.
        deltas = bdeu.loop_insert_scores(
            data, arities, y, pm, ess, max_q, r_max, counts_impl,
            pids=pids) - base
    else:
        def per_parent(x):
            return bdeu.local_score_masked(
                data, arities, y, pm.at[x].set(False), ess, max_q, r_max,
                counts_impl)

        deltas = jax.vmap(per_parent)(cand) - base

    in_pa = jnp.take(pm, cand)
    legal = (cand != y) & (~in_pa if insert else in_pa)
    return jnp.where(legal, deltas, NEG_INF)


@partial(jax.jit, static_argnames=("ess", "max_q", "r_max", "counts_impl",
                                   "kind"))
def _sweep_column(data, arities, adj, y, pids, ess, max_q, r_max,
                  counts_impl, kind):
    return sweep_column_body(data, arities, adj, y, pids, ess, max_q, r_max,
                             counts_impl, kind)


# ---------------------------------------------------------------------------
# Matrix sweeps (full (n, n) delta matrices: FES/BES initialization)
# ---------------------------------------------------------------------------

def sweep_matrix_body(data, arities, adj, ess, max_q, r_max, counts_impl,
                      kind, child_chunk=None, axis_name=None,
                      axis_size: int = 1):
    """Traceable masked (n, n) delta matrix D[x, y] for toggling x -> y.

    ``axis_name``/``axis_size``: optional mesh axis over which the child
    sweep is split (scoring-TP inside a ring process; see bdeu._deltas_impl).
    """
    insert = _check_kind(kind)
    fn = bdeu.insert_deltas if insert else bdeu.delete_deltas
    D = fn(data, arities, adj, ess, max_q, r_max, counts_impl, child_chunk,
           axis_name=axis_name, axis_size=axis_size)
    n = adj.shape[0]
    eye = jnp.eye(n, dtype=bool)
    has_edge = adj.astype(bool)
    legal = (~has_edge if insert else has_edge) & ~eye
    return jnp.where(legal, D, NEG_INF)


@partial(jax.jit, static_argnames=("ess", "max_q", "r_max", "counts_impl",
                                   "kind", "child_chunk"))
def _sweep_matrix(data, arities, adj, ess, max_q, r_max, counts_impl, kind,
                  child_chunk):
    return sweep_matrix_body(data, arities, adj, ess, max_q, r_max,
                             counts_impl, kind, child_chunk)


# ---------------------------------------------------------------------------
# Restricted matrix sweeps (the compiled ring's W-wide per-round rescoring)
# ---------------------------------------------------------------------------

def sweep_matrix_restricted_body(data, arities, adj, pid_table, ess, max_q,
                                 r_max, counts_impl, kind, child_chunk=None,
                                 axis_name=None, axis_size: int = 1):
    """Traceable masked (W, n) delta matrix over a static candidate table.

    ``pid_table``: (n, W) int32, row y = the candidate parents of child y
    (the ring's E_i column, self-padded to the static width W).  Entry
    [w, y] is the masked delta for toggling ``pid_table[y, w] -> y`` — the
    same engine-masked values a full (n, n) sweep would put at
    ``[pid_table[y, w], y]``, with padding slots (and any other illegal
    toggle) at -inf.  Every backend pays W-wide column cost: the loop engine
    builds W tables per child, the fused engines gather the W candidate data
    columns *before* the joint contraction (insert) / build the W
    marginalization maps only (delete).

    ``axis_name``/``axis_size``: optional mesh axis over which the child
    sweep is split (scoring-TP inside a ring process, mirroring
    :func:`sweep_matrix_body`): each device scores n/axis_size children's
    W-wide columns, then an all-gather reassembles the (W, n) matrix.
    """
    _check_kind(kind)
    n = adj.shape[0]

    def per_child(args):
        y, pids = args
        return sweep_column_body(data, arities, adj, y, pids, ess, max_q,
                                 r_max, counts_impl, kind)

    if counts_impl == "fused" and child_chunk is None:
        # Same memory bound as bdeu._deltas_impl: a fused child column
        # materializes an (m, W*r_max) one-hot — map children sequentially so
        # one slab lives at a time.  ("fused_pallas" builds one-hots
        # in-kernel and cannot ride lax.map on jax 0.4.x; it vmaps.)
        child_chunk = 1

    def map_children(ids, rows):
        cnt = ids.shape[0]
        if child_chunk is None or child_chunk >= cnt:
            return jax.vmap(per_child)((ids, rows))              # (cnt, W)
        return jax.lax.map(per_child, (ids, rows),
                           batch_size=min(child_chunk, cnt))

    if axis_name is not None:
        per = -(-n // axis_size)                    # children per device
        i = jax.lax.axis_index(axis_name)
        ids = jnp.clip(i * per + jnp.arange(per), 0, n - 1).astype(jnp.int32)
        cols_l = map_children(ids, jnp.take(pid_table, ids, axis=0))
        cols = jax.lax.all_gather(cols_l, axis_name, axis=0,
                                  tiled=True)[:n]                # (n, W)
        return cols.T
    children = jnp.arange(n, dtype=jnp.int32)
    return map_children(children, pid_table).T                   # (W, n)


@partial(jax.jit, static_argnames=("ess", "max_q", "r_max", "counts_impl",
                                   "kind", "child_chunk"))
def _sweep_matrix_restricted(data, arities, adj, pid_table, ess, max_q, r_max,
                             counts_impl, kind, child_chunk):
    return sweep_matrix_restricted_body(data, arities, adj, pid_table, ess,
                                        max_q, r_max, counts_impl, kind,
                                        child_chunk)


# ---------------------------------------------------------------------------
# The single public entry point
# ---------------------------------------------------------------------------

def sweep(
    data: Array,
    arities: Array,
    adj: Array,
    *,
    kind: str,
    ess: float,
    max_q: int,
    r_max: int,
    counts_impl: str = "segment",
    y: Optional[int] = None,
    pids: Optional[Array] = None,
    pid_table: Optional[Array] = None,
    child_chunk: Optional[int] = None,
) -> Array:
    """Masked BDeu delta sweep — the one API behind GES, the ring, and cGES.

    * ``kind="insert"`` / ``"delete"`` — FES / BES candidate rescoring.
    * ``y=None`` — full (n, n) delta matrix over all children;
      ``y=<child>`` — the (n,) column for one child.
    * ``pids=None`` — all n candidates; ``pids=<(W,) int32>`` — the
      restricted subset (ring E_i), returning a (W,) column whose cost
      scales with W under every backend.
    * ``pid_table=<(n, W) int32>`` (matrix sweeps only) — per-child
      restricted candidates, returning the masked (W, n) delta matrix whose
      entry [w, y] toggles ``pid_table[y, w] -> y``; the compiled ring's
      W-wide per-round rescoring.

    Candidate ids are validated up front: a ``pids``/``pid_table`` whose
    width exceeds n or that contains ids outside [0, n) raises ValueError
    instead of silently gathering wrong shapes.

    Dispatches to the loop / fused-jnp / fused-Pallas backend named by
    ``counts_impl``; all backends return identical masked columns (see the
    module docstring for the -inf convention at illegal toggles).
    """
    _check_kind(kind)
    bdeu.check_counts_impl(counts_impl)
    n = adj.shape[0]
    if pid_table is not None:
        if y is not None or pids is not None:
            raise ValueError("pid_table is a whole-matrix restriction — "
                             "pass either pid_table or (y, pids), not both")
        pid_table = _check_pids(pid_table, n, name="pid_table")
        if pid_table.ndim != 2 or pid_table.shape[0] != n:
            raise ValueError(f"pid_table must be (n, W) = ({n}, W), got "
                             f"{pid_table.shape}")
        return _sweep_matrix_restricted(data, arities, adj, pid_table, ess,
                                        max_q, r_max, counts_impl, kind,
                                        child_chunk)
    if y is None:
        if pids is not None:
            raise ValueError("pids restriction requires a column sweep "
                             "(pass y) — for a restricted matrix pass "
                             "pid_table")
        return _sweep_matrix(data, arities, adj, ess, max_q, r_max,
                             counts_impl, kind, child_chunk)
    if pids is not None:
        pids = _check_pids(pids, n, name="pids")
        if pids.ndim != 1:
            raise ValueError(f"pids must be 1-D (W,), got {pids.shape}")
    return _sweep_column(data, arities, adj, jnp.int32(y), pids, ess, max_q,
                         r_max, counts_impl, kind)
