"""The unified sweep engine — ONE API for every GES/ring/cGES delta rescoring.

Every rescoring step of the paper's algorithms is a *sweep*: score all
candidate single-edge changes toward one child (a column) or all children
(a matrix), as a batched delta against the current graph.  This module is the
single layer every driver goes through; the per-engine primitives live in
:mod:`repro.core.bdeu` and :mod:`repro.kernels.bdeu_sweep`.

Mapping of sweep kinds onto the paper (arXiv 2409.13314, Algorithm 1 / §2.2):

* ``kind="insert"`` — the **FES** candidate sweep: deltas for adding x -> y.
  This is the "evaluate all allowed arcs in parallel" step each ring process
  performs per round, and the whole of GES's forward stage.
* ``kind="delete"`` — the **BES** candidate sweep: deltas for removing
  x -> y.  Runs inside every ring process's constrained GES and in the final
  unrestricted fine-tuning pass.
* ``pids`` (candidate subset) — the paper's **restricted edge sets E_i**: a
  ring process with |E_i| ~ n/k allowed parents per column sweeps only those
  W candidates, which is the mechanism that makes the ring cheaper than
  monolithic GES.  ``pids=None`` sweeps all n candidates (the fine-tune /
  plain-GES case).

Backends (selected by ``counts_impl``):

* ``"segment" | "onehot" | "pallas"`` — the **loop** engine: one contingency
  table build per candidate (vmapped).
* ``"fused"`` — jnp segment-sum realizations of the fused sweeps: insert
  columns from ONE joint child-value-batched contraction
  (:func:`bdeu.fused_insert_scores`), delete columns from ONE family-table
  build marginalized over each parent slot
  (:func:`bdeu.fused_delete_scores`).
* ``"fused_pallas"`` — same math with the tiled Pallas kernels
  (``kernels/bdeu_sweep`` for insert contractions, ``kernels/bdeu_count``
  for the delete sweep's single family table).

Convention (stronger than the raw bdeu primitives): returned columns and
matrices are **masked** — entries that are not a legal toggle (self-loops,
inserting an existing edge, deleting a missing edge, candidates outside a
``pids`` subset's real extent via self-padding) are -inf under EVERY backend,
so callers cannot select them by forgetting a mask and all backends agree
entry-for-entry.  Graph-level validity (acyclicity, max_parents, allowed-edge
sets E_i, q-guard for inserts) remains the caller's mask, as before.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import bdeu

Array = jax.Array
NEG_INF = -jnp.inf

KINDS = ("insert", "delete")


def _check_kind(kind: str) -> bool:
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    return kind == "insert"


# ---------------------------------------------------------------------------
# Column sweeps (incremental rescoring: only column y changed)
# ---------------------------------------------------------------------------

def sweep_column_body(data, arities, adj, y, pids, ess, max_q, r_max,
                      counts_impl, kind):
    """Traceable masked delta column — callable from inside jit/shard_map.

    Returns (n,) deltas for toggling x -> y over all candidates x, or (W,)
    over the ``pids`` subset.  See the module docstring for the masking
    convention; with a fused ``counts_impl`` the whole column costs one joint
    contraction (insert) or one family-table build (delete) instead of one
    table build per candidate.
    """
    insert = _check_kind(kind)
    n = adj.shape[0]
    pm = adj.astype(bool)[:, y]
    base = bdeu.local_score_masked(
        data, arities, y, pm, ess, max_q, r_max, counts_impl)
    cand = jnp.arange(n, dtype=jnp.int32) if pids is None else pids

    if counts_impl in bdeu.FUSED_IMPLS:
        fn = bdeu.fused_insert_scores if insert else bdeu.fused_delete_scores
        deltas = fn(data, arities, y, pm, ess, max_q, r_max, counts_impl,
                    pids=pids) - base
    else:
        def per_parent(x):
            return bdeu.local_score_masked(
                data, arities, y, pm.at[x].set(insert), ess, max_q, r_max,
                counts_impl)

        deltas = jax.vmap(per_parent)(cand) - base

    in_pa = jnp.take(pm, cand)
    legal = (cand != y) & (~in_pa if insert else in_pa)
    return jnp.where(legal, deltas, NEG_INF)


@partial(jax.jit, static_argnames=("ess", "max_q", "r_max", "counts_impl",
                                   "kind"))
def _sweep_column(data, arities, adj, y, pids, ess, max_q, r_max,
                  counts_impl, kind):
    return sweep_column_body(data, arities, adj, y, pids, ess, max_q, r_max,
                             counts_impl, kind)


# ---------------------------------------------------------------------------
# Matrix sweeps (full (n, n) delta matrices: FES/BES initialization)
# ---------------------------------------------------------------------------

def sweep_matrix_body(data, arities, adj, ess, max_q, r_max, counts_impl,
                      kind, child_chunk=None, axis_name=None,
                      axis_size: int = 1):
    """Traceable masked (n, n) delta matrix D[x, y] for toggling x -> y.

    ``axis_name``/``axis_size``: optional mesh axis over which the child
    sweep is split (scoring-TP inside a ring process; see bdeu._deltas_impl).
    """
    insert = _check_kind(kind)
    fn = bdeu.insert_deltas if insert else bdeu.delete_deltas
    D = fn(data, arities, adj, ess, max_q, r_max, counts_impl, child_chunk,
           axis_name=axis_name, axis_size=axis_size)
    n = adj.shape[0]
    eye = jnp.eye(n, dtype=bool)
    has_edge = adj.astype(bool)
    legal = (~has_edge if insert else has_edge) & ~eye
    return jnp.where(legal, D, NEG_INF)


@partial(jax.jit, static_argnames=("ess", "max_q", "r_max", "counts_impl",
                                   "kind", "child_chunk"))
def _sweep_matrix(data, arities, adj, ess, max_q, r_max, counts_impl, kind,
                  child_chunk):
    return sweep_matrix_body(data, arities, adj, ess, max_q, r_max,
                             counts_impl, kind, child_chunk)


# ---------------------------------------------------------------------------
# The single public entry point
# ---------------------------------------------------------------------------

def sweep(
    data: Array,
    arities: Array,
    adj: Array,
    *,
    kind: str,
    ess: float,
    max_q: int,
    r_max: int,
    counts_impl: str = "segment",
    y: Optional[int] = None,
    pids: Optional[Array] = None,
    child_chunk: Optional[int] = None,
) -> Array:
    """Masked BDeu delta sweep — the one API behind GES, the ring, and cGES.

    * ``kind="insert"`` / ``"delete"`` — FES / BES candidate rescoring.
    * ``y=None`` — full (n, n) delta matrix over all children;
      ``y=<child>`` — the (n,) column for one child.
    * ``pids=None`` — all n candidates; ``pids=<(W,) int32>`` — the
      restricted subset (ring E_i), returning a (W,) column whose cost
      scales with W under every backend.

    Dispatches to the loop / fused-jnp / fused-Pallas backend named by
    ``counts_impl``; all backends return identical masked columns (see the
    module docstring for the -inf convention at illegal toggles).
    """
    _check_kind(kind)
    if y is None:
        if pids is not None:
            raise ValueError("pids restriction requires a column sweep "
                             "(pass y)")
        return _sweep_matrix(data, arities, adj, ess, max_q, r_max,
                             counts_impl, kind, child_chunk)
    return _sweep_column(data, arities, adj, jnp.int32(y), pids, ess, max_q,
                         r_max, counts_impl, kind)
