"""The unified sweep engine — ONE API for every GES/ring/cGES delta rescoring.

Every rescoring step of the paper's algorithms is a *sweep*: score all
candidate single-edge changes toward one child (a column) or all children
(a matrix), as a batched delta against the current graph.  This module is the
single layer every driver goes through; the per-engine primitives live in
:mod:`repro.core.bdeu` and :mod:`repro.kernels.bdeu_sweep`.

Mapping of sweep kinds onto the paper (arXiv 2409.13314, Algorithm 1 / §2.2):

* ``kind="insert"`` — the **FES** candidate sweep: deltas for adding x -> y.
  This is the "evaluate all allowed arcs in parallel" step each ring process
  performs per round, and the whole of GES's forward stage.
* ``kind="delete"`` — the **BES** candidate sweep: deltas for removing
  x -> y.  Runs inside every ring process's constrained GES and in the final
  unrestricted fine-tuning pass.
* ``pids`` (candidate subset) — the paper's **restricted edge sets E_i**: a
  ring process with |E_i| ~ n/k allowed parents per column sweeps only those
  W candidates, which is the mechanism that makes the ring cheaper than
  monolithic GES.  ``pids=None`` sweeps all n candidates (the fine-tune /
  plain-GES case).
* ``pid_table`` (static (n, W) candidate table, one ``pids`` row per child —
  see :func:`repro.core.partition.pid_table_from_allowed`) — the whole-round
  restricted sweep: a masked **(W, n)** delta matrix whose entry [w, y] is
  the delta for toggling ``pid_table[y, w] -> y``.  This is what the
  compiled ``ges_jit``/shard_map-ring path initializes FES/BES from, so the
  fully-compiled ring pays W-wide matrix sweeps end-to-end instead of
  sweeping full-n and masking afterwards.  Rows are self-padded (pad slots
  hold ``y``), and padding comes back -inf like any other illegal toggle.

Backends (selected by ``counts_impl``):

* ``"segment" | "onehot" | "pallas"`` — the **loop** engine: one contingency
  table build per candidate (vmapped).
* ``"fused"`` — jnp segment-sum realizations of the fused sweeps: insert
  columns from ONE joint child-value-batched contraction
  (:func:`bdeu.fused_insert_scores`), delete columns from ONE family-table
  build marginalized over each parent slot
  (:func:`bdeu.fused_delete_scores`).
* ``"fused_pallas"`` — same math with the tiled Pallas kernels
  (``kernels/bdeu_sweep``): insert columns run the joint one-hot
  contraction kernel, and delete columns run the **VMEM-resident** delete
  kernel (``delete_scores``) — the one current-family (max_q, r) table is
  accumulated in VMEM scratch and each parent slot's marginal is reduced
  straight to its BDeu score in-kernel, so the table never round-trips
  through HBM and only the (n,)/(W,) score column is written back
  (interpret mode on CPU, compiled on TPU; identical masking/guard
  conventions to the jnp engines).

Convention (stronger than the raw bdeu primitives): returned columns and
matrices are **masked** — entries that are not a legal toggle (self-loops,
inserting an existing edge, deleting a missing edge, candidates outside a
``pids`` subset's real extent via self-padding) are -inf under EVERY backend,
so callers cannot select them by forgetting a mask and all backends agree
entry-for-entry.  Graph-level validity (acyclicity, max_parents, allowed-edge
sets E_i, q-guard for inserts) remains the caller's mask, as before.

Two ORTHOGONAL mesh axes
------------------------

Sweeps can be distributed along two independent mesh axes that compose into
a 2-D (or, with the ring, 3-D) device mesh:

* **scoring-TP** (``axis_name``/``axis_size`` on the matrix bodies): the
  CHILD axis is split — each device scores n/axis_size children's columns
  and an ``all_gather`` reassembles the delta matrix.  Work partitioning;
  every device still reads the full (m, n) data shard it holds.
* **data axis** (``data_axis_name``, new): the INSTANCE axis is split —
  each device holds only an m/d row-shard of ``data`` and contracts it into
  partial contingency tables; ONE ``psum`` per table (placed inside the bdeu
  primitives / kernel ops wrappers, before the m-independent BDeu reduction)
  rebuilds the global counts.  Ragged m is padded with sentinel rows of
  value ``r_max`` (out of range for every variable — counting-neutral in
  all backends), so sharded sweeps are table-identical to single-device.
  The VMEM Pallas delete kernel reduces counts to scores in-register
  (scores are NOT shard-additive), so under data sharding
  ``"fused_pallas"`` deletes route to the two-step psum-able path.

The host-facing switch is ``sweep(..., data_shards=d)``, which pads, builds
a cached jitted ``shard_map`` over a d-device ``("data",)`` mesh and runs
the same bodies inside it.  The compiled ring threads ``data_axis_name``
explicitly through ``ges_jit_body`` on a 2-D (ring x data) mesh.

Family-score cache
------------------

:func:`sweep_column_cached` guards a column sweep with the persistent
device-resident cache of :mod:`repro.core.score_cache`.  Key = exact packed
``(kind, child, parent-bitmask-of-child, scope)`` — the column is a pure
function of those (plus the static sweep program), ``scope`` naming the
candidate restriction (ring members hash their allowed column into it; 0
for full-n).  Keys match word-for-word (the hash only places entries in a
set-associative table), so cached trajectories are bitwise-identical to
uncached.  Eviction is prioritized: recency step + a bounded bonus for
columns still holding a positive delta (PER-flavoured).  See the
score_cache module docstring for the full contract.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import bdeu, score_cache

Array = jax.Array
NEG_INF = -jnp.inf

KINDS = ("insert", "delete")

# Mesh-axis name used by the host-facing ``sweep(..., data_shards=d)`` path.
DATA_AXIS = "data"

KIND_CODES = {"insert": score_cache.KIND_INSERT,
              "delete": score_cache.KIND_DELETE}


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions (collectives validate replication
    rules we intentionally break: psum-of-counts produces replicated outputs
    the checker cannot see).  Disables check_rep/check_vma where present."""
    from jax.experimental.shard_map import shard_map as _sm
    try:
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    except TypeError:  # pragma: no cover - newer jax renamed the flag
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)


def pad_data_rows(data: Array, r_max: int, d: int) -> Array:
    """Pad the instance axis to a multiple of ``d`` with sentinel rows.

    Sentinel value ``r_max`` is out of range for EVERY variable (values are
    0..arity-1 <= r_max - 1), which all count backends treat as
    counting-neutral (zero one-hot rows / explicit overflow segments /
    kernel sentinel contract) — so ragged m % d != 0 sharding is exact.
    """
    m = int(data.shape[0])
    m_pad = ((m + d - 1) // d) * d
    if m_pad == m:
        return data
    pad = jnp.full((m_pad - m, data.shape[1]), r_max, dtype=data.dtype)
    return jnp.concatenate([data, pad], axis=0)


def _check_kind(kind: str) -> bool:
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    return kind == "insert"


def _check_pids(pids, n: int, name: str = "pids") -> Array:
    """Validate a candidate-id vector/table against the variable count n.

    Shape problems (wrong rank, more candidates than variables) and
    out-of-range ids raise ``ValueError`` immediately instead of flowing into
    the gather as silent wrong shapes / clamped indices.  Value checks are
    skipped for traced arrays (inside jit the caller's ids are assumed
    pre-validated — the public ``sweep`` entry point sees concrete arrays).
    """
    pids = jnp.asarray(pids)
    if not jnp.issubdtype(pids.dtype, jnp.integer):
        raise ValueError(f"{name} must be integer-typed, got {pids.dtype}")
    width = pids.shape[-1]
    if width > n:
        raise ValueError(
            f"{name} has {width} candidates per column but only n = {n} "
            f"variables exist — pad with the child id (self-loop), not by "
            f"exceeding n")
    if not isinstance(pids, jax.core.Tracer) and pids.size:
        vals = np.asarray(pids)
        if vals.min() < 0 or vals.max() >= n:
            bad = vals[(vals < 0) | (vals >= n)]
            raise ValueError(
                f"{name} contains out-of-range variable ids {bad[:8]} "
                f"(valid range [0, {n}))")
    return pids


# ---------------------------------------------------------------------------
# Column sweeps (incremental rescoring: only column y changed)
# ---------------------------------------------------------------------------

def sweep_column_body(data, arities, adj, y, pids, ess, max_q, r_max,
                      counts_impl, kind, data_axis_name=None):
    """Traceable masked delta column — callable from inside jit/shard_map.

    Returns (n,) deltas for toggling x -> y over all candidates x, or (W,)
    over the ``pids`` subset.  See the module docstring for the masking
    convention; with a fused ``counts_impl`` the whole column costs one joint
    contraction (insert) or one family-table build (delete) instead of one
    table build per candidate.  With ``data_axis_name`` every count build
    contracts the local m/d shard and psums (module docstring: data axis).
    """
    insert = _check_kind(kind)
    n = adj.shape[0]
    pm = adj.astype(bool)[:, y]
    base = bdeu.local_score_masked(
        data, arities, y, pm, ess, max_q, r_max, counts_impl,
        data_axis_name=data_axis_name)
    cand = jnp.arange(n, dtype=jnp.int32) if pids is None else pids

    if counts_impl in bdeu.FUSED_IMPLS:
        fn = bdeu.fused_insert_scores if insert else bdeu.fused_delete_scores
        deltas = fn(data, arities, y, pm, ess, max_q, r_max, counts_impl,
                    pids=pids, data_axis_name=data_axis_name) - base
    elif insert:
        # The ONE loop-engine insert primitive (incremental config
        # encoding) — shared with bdeu._deltas_impl's full matrix, so a
        # restricted column is bitwise equal to the matching full-n
        # matrix entries and full-n tie-breaks transfer exactly.
        deltas = bdeu.loop_insert_scores(
            data, arities, y, pm, ess, max_q, r_max, counts_impl,
            pids=pids, data_axis_name=data_axis_name) - base
    else:
        def per_parent(x):
            return bdeu.local_score_masked(
                data, arities, y, pm.at[x].set(False), ess, max_q, r_max,
                counts_impl, data_axis_name=data_axis_name)

        deltas = jax.vmap(per_parent)(cand) - base

    in_pa = jnp.take(pm, cand)
    legal = (cand != y) & (~in_pa if insert else in_pa)
    return jnp.where(legal, deltas, NEG_INF)


@partial(jax.jit, static_argnames=("ess", "max_q", "r_max", "counts_impl",
                                   "kind"))
def _sweep_column(data, arities, adj, y, pids, ess, max_q, r_max,
                  counts_impl, kind):
    return sweep_column_body(data, arities, adj, y, pids, ess, max_q, r_max,
                             counts_impl, kind)


# ---------------------------------------------------------------------------
# Matrix sweeps (full (n, n) delta matrices: FES/BES initialization)
# ---------------------------------------------------------------------------

def sweep_matrix_body(data, arities, adj, ess, max_q, r_max, counts_impl,
                      kind, child_chunk=None, axis_name=None,
                      axis_size: int = 1, data_axis_name=None):
    """Traceable masked (n, n) delta matrix D[x, y] for toggling x -> y.

    ``axis_name``/``axis_size``: optional mesh axis over which the child
    sweep is split (scoring-TP inside a ring process; see bdeu._deltas_impl).
    ``data_axis_name``: optional ORTHOGONAL mesh axis sharding the instance
    axis (module docstring) — composes freely with the child split.
    """
    insert = _check_kind(kind)
    fn = bdeu.insert_deltas if insert else bdeu.delete_deltas
    D = fn(data, arities, adj, ess, max_q, r_max, counts_impl, child_chunk,
           axis_name=axis_name, axis_size=axis_size,
           data_axis_name=data_axis_name)
    n = adj.shape[0]
    eye = jnp.eye(n, dtype=bool)
    has_edge = adj.astype(bool)
    legal = (~has_edge if insert else has_edge) & ~eye
    return jnp.where(legal, D, NEG_INF)


@partial(jax.jit, static_argnames=("ess", "max_q", "r_max", "counts_impl",
                                   "kind", "child_chunk"))
def _sweep_matrix(data, arities, adj, ess, max_q, r_max, counts_impl, kind,
                  child_chunk):
    return sweep_matrix_body(data, arities, adj, ess, max_q, r_max,
                             counts_impl, kind, child_chunk)


# ---------------------------------------------------------------------------
# Restricted matrix sweeps (the compiled ring's W-wide per-round rescoring)
# ---------------------------------------------------------------------------

def sweep_matrix_restricted_body(data, arities, adj, pid_table, ess, max_q,
                                 r_max, counts_impl, kind, child_chunk=None,
                                 axis_name=None, axis_size: int = 1,
                                 data_axis_name=None):
    """Traceable masked (W, n) delta matrix over a static candidate table.

    ``pid_table``: (n, W) int32, row y = the candidate parents of child y
    (the ring's E_i column, self-padded to the static width W).  Entry
    [w, y] is the masked delta for toggling ``pid_table[y, w] -> y`` — the
    same engine-masked values a full (n, n) sweep would put at
    ``[pid_table[y, w], y]``, with padding slots (and any other illegal
    toggle) at -inf.  Every backend pays W-wide column cost: the loop engine
    builds W tables per child, the fused engines gather the W candidate data
    columns *before* the joint contraction (insert) / build the W
    marginalization maps only (delete).

    ``axis_name``/``axis_size``: optional mesh axis over which the child
    sweep is split (scoring-TP inside a ring process, mirroring
    :func:`sweep_matrix_body`): each device scores n/axis_size children's
    W-wide columns, then an all-gather reassembles the (W, n) matrix.
    """
    _check_kind(kind)
    n = adj.shape[0]

    def per_child(args):
        y, pids = args
        return sweep_column_body(data, arities, adj, y, pids, ess, max_q,
                                 r_max, counts_impl, kind,
                                 data_axis_name=data_axis_name)

    if counts_impl == "fused" and child_chunk is None:
        # Same memory bound as bdeu._deltas_impl: a fused child column
        # materializes an (m, W*r_max) one-hot — map children sequentially so
        # one slab lives at a time.  ("fused_pallas" builds one-hots
        # in-kernel and cannot ride lax.map on jax 0.4.x; it vmaps.)
        child_chunk = 1

    def map_children(ids, rows):
        cnt = ids.shape[0]
        if child_chunk is None or child_chunk >= cnt:
            return jax.vmap(per_child)((ids, rows))              # (cnt, W)
        return jax.lax.map(per_child, (ids, rows),
                           batch_size=min(child_chunk, cnt))

    if axis_name is not None:
        per = -(-n // axis_size)                    # children per device
        i = jax.lax.axis_index(axis_name)
        ids = jnp.clip(i * per + jnp.arange(per), 0, n - 1).astype(jnp.int32)
        cols_l = map_children(ids, jnp.take(pid_table, ids, axis=0))
        cols = jax.lax.all_gather(cols_l, axis_name, axis=0,
                                  tiled=True)[:n]                # (n, W)
        return cols.T
    children = jnp.arange(n, dtype=jnp.int32)
    return map_children(children, pid_table).T                   # (W, n)


@partial(jax.jit, static_argnames=("ess", "max_q", "r_max", "counts_impl",
                                   "kind", "child_chunk"))
def _sweep_matrix_restricted(data, arities, adj, pid_table, ess, max_q, r_max,
                             counts_impl, kind, child_chunk):
    return sweep_matrix_restricted_body(data, arities, adj, pid_table, ess,
                                        max_q, r_max, counts_impl, kind,
                                        child_chunk)


# ---------------------------------------------------------------------------
# Cache-guarded column sweeps (persistent family-score cache)
# ---------------------------------------------------------------------------

def sweep_column_cached(cache, data, arities, adj, y, pids, ess, max_q,
                        r_max, counts_impl, kind, scope=0,
                        data_axis_name=None):
    """Column sweep guarded by the persistent family-score cache.

    Returns ``(col, cache')``.  On a hit the whole column compute (the O(m)
    count contraction) is skipped via ``lax.cond``; on a miss the computed
    column is stored with prioritized eviction.  Key = exact packed
    (kind, y, parents-of-y, scope) — see :mod:`repro.core.score_cache` for
    why cached trajectories are bitwise-identical to uncached.  Traceable:
    lives inside ``lax.while_loop``/``lax.scan`` (the compiled FES/BES
    loops thread ``cache`` through their carries).
    """
    _check_kind(kind)
    pm = adj.astype(bool)[:, y]

    def compute():
        return sweep_column_body(data, arities, adj, y, pids, ess, max_q,
                                 r_max, counts_impl, kind,
                                 data_axis_name=data_axis_name)

    return score_cache.lookup_or_compute(
        cache, KIND_CODES[kind], y, pm, scope, compute)


# ---------------------------------------------------------------------------
# Host-facing data-axis sharding: sweep(..., data_shards=d)
# ---------------------------------------------------------------------------

def _data_mesh(d: int) -> Mesh:
    devs = jax.devices()
    if len(devs) < d:
        raise ValueError(
            f"data_shards={d} needs {d} devices but only {len(devs)} are "
            f"visible — on CPU set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={d} before importing jax")
    return Mesh(np.array(devs[:d]), (DATA_AXIS,))


@lru_cache(maxsize=None)
def _sharded_sweep_fn(d: int, mode: str, has_pids: bool, ess, max_q: int,
                      r_max: int, counts_impl: str, kind: str,
                      child_chunk):
    """Cached jitted shard_map program for one static sweep configuration.

    ``mode``: "column" | "matrix" | "matrix_restricted".  Data is sharded
    along the mesh's data axis; everything else is replicated, and the
    psum'd result is replicated (identical on every device) by construction.
    """
    mesh = _data_mesh(d)

    if mode == "column":
        if has_pids:
            def body(data, arities, adj, y, pids):
                return sweep_column_body(
                    data, arities, adj, y, pids, ess, max_q, r_max,
                    counts_impl, kind, data_axis_name=DATA_AXIS)
            in_specs = (P(DATA_AXIS), P(), P(), P(), P())
        else:
            def body(data, arities, adj, y):
                return sweep_column_body(
                    data, arities, adj, y, None, ess, max_q, r_max,
                    counts_impl, kind, data_axis_name=DATA_AXIS)
            in_specs = (P(DATA_AXIS), P(), P(), P())
    elif mode == "matrix":
        def body(data, arities, adj):
            return sweep_matrix_body(
                data, arities, adj, ess, max_q, r_max, counts_impl, kind,
                child_chunk, data_axis_name=DATA_AXIS)
        in_specs = (P(DATA_AXIS), P(), P())
    else:
        def body(data, arities, adj, pid_table):
            return sweep_matrix_restricted_body(
                data, arities, adj, pid_table, ess, max_q, r_max,
                counts_impl, kind, child_chunk, data_axis_name=DATA_AXIS)
        in_specs = (P(DATA_AXIS), P(), P(), P())

    return jax.jit(shard_map_compat(body, mesh, in_specs, P()))


# ---------------------------------------------------------------------------
# The single public entry point
# ---------------------------------------------------------------------------

def sweep(
    data: Array,
    arities: Array,
    adj: Array,
    *,
    kind: str,
    ess: float,
    max_q: int,
    r_max: int,
    counts_impl: str = "segment",
    y: Optional[int] = None,
    pids: Optional[Array] = None,
    pid_table: Optional[Array] = None,
    child_chunk: Optional[int] = None,
    data_shards: int = 1,
) -> Array:
    """Masked BDeu delta sweep — the one API behind GES, the ring, and cGES.

    * ``kind="insert"`` / ``"delete"`` — FES / BES candidate rescoring.
    * ``y=None`` — full (n, n) delta matrix over all children;
      ``y=<child>`` — the (n,) column for one child.
    * ``pids=None`` — all n candidates; ``pids=<(W,) int32>`` — the
      restricted subset (ring E_i), returning a (W,) column whose cost
      scales with W under every backend.
    * ``pid_table=<(n, W) int32>`` (matrix sweeps only) — per-child
      restricted candidates, returning the masked (W, n) delta matrix whose
      entry [w, y] toggles ``pid_table[y, w] -> y``; the compiled ring's
      W-wide per-round rescoring.

    Candidate ids are validated up front: a ``pids``/``pid_table`` whose
    width exceeds n or that contains ids outside [0, n) raises ValueError
    instead of silently gathering wrong shapes.

    ``data_shards=d`` (> 1) shards the INSTANCE axis over a d-device
    ``("data",)`` mesh: ragged m is padded with counting-neutral sentinel
    rows, each device contracts its m/d shard and one psum per table
    rebuilds the global counts — results are table-identical to
    ``data_shards=1`` under every backend (module docstring: data axis).

    Dispatches to the loop / fused-jnp / fused-Pallas backend named by
    ``counts_impl``; all backends return identical masked columns (see the
    module docstring for the -inf convention at illegal toggles).
    """
    _check_kind(kind)
    bdeu.check_counts_impl(counts_impl)
    n = adj.shape[0]
    d = 1 if data_shards is None else int(data_shards)
    if d < 1:
        raise ValueError(f"data_shards must be >= 1, got {data_shards}")
    if d > 1:
        data = pad_data_rows(jnp.asarray(data), r_max, d)
    if pid_table is not None:
        if y is not None or pids is not None:
            raise ValueError("pid_table is a whole-matrix restriction — "
                             "pass either pid_table or (y, pids), not both")
        pid_table = _check_pids(pid_table, n, name="pid_table")
        if pid_table.ndim != 2 or pid_table.shape[0] != n:
            raise ValueError(f"pid_table must be (n, W) = ({n}, W), got "
                             f"{pid_table.shape}")
        if d > 1:
            fn = _sharded_sweep_fn(d, "matrix_restricted", True, ess, max_q,
                                   r_max, counts_impl, kind, child_chunk)
            return fn(data, arities, adj, pid_table)
        return _sweep_matrix_restricted(data, arities, adj, pid_table, ess,
                                        max_q, r_max, counts_impl, kind,
                                        child_chunk)
    if y is None:
        if pids is not None:
            raise ValueError("pids restriction requires a column sweep "
                             "(pass y) — for a restricted matrix pass "
                             "pid_table")
        if d > 1:
            fn = _sharded_sweep_fn(d, "matrix", False, ess, max_q, r_max,
                                   counts_impl, kind, child_chunk)
            return fn(data, arities, adj)
        return _sweep_matrix(data, arities, adj, ess, max_q, r_max,
                             counts_impl, kind, child_chunk)
    if pids is not None:
        pids = _check_pids(pids, n, name="pids")
        if pids.ndim != 1:
            raise ValueError(f"pids must be 1-D (W,), got {pids.shape}")
    if d > 1:
        fn = _sharded_sweep_fn(d, "column", pids is not None, ess, max_q,
                               r_max, counts_impl, kind, child_chunk)
        args = (data, arities, adj, jnp.int32(y))
        return fn(*args, pids) if pids is not None else fn(*args)
    return _sweep_column(data, arities, adj, jnp.int32(y), pids, ess, max_q,
                         r_max, counts_impl, kind)
