"""repro.core — the paper's contribution: cGES, ring-distributed structural
learning of Bayesian networks with GES guarantees."""
from .ges import (DeviceFamilyCache, GESConfig, GESResult, ScoreCache,
                  device_data, ges_host, ges_jit)
from .fges import fges_host
from .cges import CGESResult, cges, edge_add_limit
from .partition import (partition_edges, variable_clusters, edge_subsets,
                        remerge_failed, pid_table_from_allowed, pid_tables)
from .fusion import (fuse, fuse_trace, fusion_edge_union, sigma_consistent,
                     gho_order, check_fusion_engine, resolve_fusion_engine)
from .ring import RingSpec, ring_cges, build_ring_program, fuse_jit
from .ring_async import (AsyncRingSpec, run_member, run_ring_async_threads,
                         send_frame, recv_frame)
from .score_cache import FamilyScoreCache
from .sweeps import pad_data_rows, sweep
from . import bdeu, dag, metrics, score_cache, sweeps
