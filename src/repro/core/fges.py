"""fGES baseline (Ramsey et al. 2017).

The defining approximations of fGES relative to GES:
  * a *first pass* scores every pairwise arrow from the empty graph, and only
    arrows whose first-pass delta is positive ("effect edges") are ever
    considered again — this is the source of both its speed and its quality
    gap on dense domains (paper Table 2: low BDeu / high SMHD on pigs, link);
  * candidate (re)scoring is embarrassingly parallel — realized here as the
    same batched jit sweeps used by our GES engine;
  * BES runs unrestricted, as in GES.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np
import jax.numpy as jnp

from .ges import GESConfig, GESResult, ges_host
from .sweeps import sweep


def fges_host(
    data: np.ndarray,
    arities: np.ndarray,
    config: Optional[GESConfig] = None,
) -> GESResult:
    # built per call, not bound at import — honours REPRO_COUNTS_IMPL set
    # after ``import repro`` (see GESConfig.counts_impl)
    config = config if config is not None else GESConfig()
    m, n = data.shape
    r_max = int(arities.max())
    # First pass: pairwise deltas from the empty graph (one batched sweep
    # through the unified engine; illegal entries come back -inf).
    d0 = np.asarray(sweep(
        jnp.asarray(data.astype(np.int32)),
        jnp.asarray(arities.astype(np.int32)),
        jnp.zeros((n, n), dtype=jnp.int8),
        kind="insert", ess=config.ess, max_q=config.max_q, r_max=r_max,
        counts_impl=config.counts_impl,
    ))
    effect = d0 > config.tol
    np.fill_diagonal(effect, False)

    # FES restricted to effect edges; BES unrestricted (as in fGES).
    res_fes = ges_host(data, arities, allowed=effect, config=config,
                       phases="fes")
    res = ges_host(data, arities, init_adj=res_fes.adj, allowed=None,
                   config=config, phases="bes")
    return GESResult(
        adj=res.adj, score=res.score,
        n_inserts=res_fes.n_inserts,
        n_deletes=res.n_deletes,
        n_score_evals=n * n + res_fes.n_score_evals + res.n_score_evals,
    )
