"""The asynchronous, double-buffered, elastic ring — cGES stage 2 as a true
multi-process distributed system.

``core/ring.py`` is the lockstep oracle: one single-process ``shard_map``
program whose every round is a global barrier (ppermute -> fuse -> sweep ->
pmax).  This module is the deployment shape the paper actually describes —
k processes working *concurrently* on restricted edge subsets — with three
properties the compiled program cannot express:

* **asynchronous rounds** — a member posts its round-t BN to its ring
  successor the moment its sweep finishes (a background sender thread owns
  the socket, so the (W, n) sweep of round t+1 starts immediately) and
  begins round t+1 as soon as its *predecessor's* round-t BN is in the
  double-buffered mailbox — which it normally already is, because the
  transfer overlapped round t's fuse+sweep.  The per-round blocked-wait
  time is therefore the *un-overlapped* remainder of neighbor transfer,
  and is recorded per member per round (see ``timings`` in the result).
* **token convergence** — there is no global ``pmax`` barrier.  A token
  circulates the ring: the origin (first live member) injects token(t)
  after finishing round t, every member stamps its round-t score when it
  has one and forwards, and the returned token yields a verdict
  (improved / stop) that circulates back.  Members may run up to
  ``speculation`` rounds ahead of the newest verdict (default 2 — the
  double-buffer depth); speculative rounds never diverge because fusion
  and GES inputs do not depend on verdicts, so a healthy async run's
  per-member trajectory is IDENTICAL to the lockstep ring's.
* **elastic membership** — each member heartbeats its successor; a member
  whose predecessor goes silent past ``hb_timeout_s`` declares it dead,
  folds the victim's edge subset E_v into the victim's ring predecessor
  (partition.remerge_failed semantics, computed locally from the shared
  static member table), gossips the death around the ring, re-stitches its
  inbound edge, and the remaining k-1 members finish the run.  On
  re-stitch the new predecessor replays its recent BN history (bounded by
  the speculation depth, so no round can be lost).

Why the data plane is raw TCP and not jax collectives: multi-process
collectives do not exist on the CPU backend ("Multiprocess computations
aren't implemented"), collectives are bulk-synchronous (exactly the barrier
this module removes) and fixed-membership (a dead participant deadlocks the
ring), and jax's coordination service *terminates* surviving processes when
a peer dies — the opposite of elastic.  ``jax.distributed.initialize`` is
still used for what it is good at: bootstrapping the healthy multi-process
cluster (process ids, and the global device view on real multi-host
hardware); members opt in via ``AsyncRingSpec.jax_coordinator``.  The
elastic (kill-a-member) path runs with it off, and the module docchain +
tests record why.

Entry points:

* :func:`run_member` — one ring member, blocking; the unit both the
  threaded and the multi-process modes execute.
* :func:`run_ring_async_threads` — in-process mode: k members as threads
  over localhost sockets (ges_jit compilations shared); used by
  ``cges(engine="async")``, the benchmarks and most tests.
* ``repro.launch.ring_async_run`` — the multi-process launcher: k OS
  processes on a local TCP cluster (CI) or k hosts (real deployment),
  optionally bootstrapped by ``jax.distributed``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import socket
import struct
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import fusion, partition

NEG = float("-inf")
_LEN = struct.Struct(">I")


def _debug_enabled() -> bool:
    # Read at CALL time, not import time (same contract as
    # GESConfig.counts_impl's default_factory): RING_ASYNC_DEBUG set after
    # ``import repro`` must be honoured (regression-tested, lint rule R001).
    return os.environ.get("RING_ASYNC_DEBUG", "0").lower() in (
        "1", "true", "yes", "on")


def _dbg(*parts) -> None:
    if _debug_enabled():
        print(f"[ring_async {time.monotonic():.3f}]", *parts, flush=True)


# ---------------------------------------------------------------------------
# Wire protocol: 4-byte length + JSON header [+ raw payload]
# ---------------------------------------------------------------------------

def send_frame(sock: socket.socket, header: dict,
               payload: bytes = b"") -> None:
    h = dict(header)
    if payload:
        h["payload_bytes"] = len(payload)
    raw = json.dumps(h).encode()
    sock.sendall(_LEN.pack(len(raw)) + raw + payload)


def _recv_exact(f, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = f.read(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed")
        buf += chunk
    return buf


def recv_frame(f) -> Tuple[dict, bytes]:
    (hlen,) = _LEN.unpack(_recv_exact(f, 4))
    header = json.loads(_recv_exact(f, hlen).decode())
    payload = _recv_exact(f, header.get("payload_bytes", 0)) \
        if header.get("payload_bytes") else b""
    return header, payload


# ---------------------------------------------------------------------------
# Round-keyed mailbox (the double-buffered neighbor-exchange slot)
# ---------------------------------------------------------------------------

class Mailbox:
    """Round-keyed slots filled by the receiver thread, drained by the
    compute loop.  ``get(rnd)`` measures the *un-overlapped* part of the
    neighbor transfer: when the predecessor's BN arrived while this member
    was still sweeping the previous round, the get returns immediately."""

    def __init__(self):
        self._slots: Dict[int, tuple] = {}
        self._cv = threading.Condition()

    def put(self, rnd: int, item: tuple) -> None:
        with self._cv:
            # first write wins: replayed history must not overwrite
            self._slots.setdefault(rnd, item)
            self._cv.notify_all()

    def get(self, rnd: int, stop: threading.Event,
            timeout: float) -> Optional[tuple]:
        deadline = time.monotonic() + timeout
        with self._cv:
            while rnd not in self._slots:
                left = deadline - time.monotonic()
                if left <= 0 or stop.is_set():
                    return None
                self._cv.wait(min(left, 0.05))
            return self._slots[rnd]

    def drop_below(self, rnd: int) -> None:
        with self._cv:
            for r in [r for r in self._slots if r < rnd]:
                del self._slots[r]


# ---------------------------------------------------------------------------
# Outbound link: background sender w/ reconnect + history replay
# ---------------------------------------------------------------------------

class _Sender(threading.Thread):
    """Owns the outbound socket to the CURRENT ring successor.  Sends are
    enqueued (compute never blocks on the network — this is what lets the
    round-t transfer overlap the round-t+1 sweep) and the thread replays
    the member's recent BN history whenever the successor changes, so a
    re-stitched ring never loses a round."""

    def __init__(self, me: int, replay):
        super().__init__(daemon=True)
        self._me = me
        self._replay = replay              # () -> list[(header, payload)]
        self._q: deque = deque()
        self._cv = threading.Condition()
        self._target: Optional[Tuple[str, int]] = None
        self._retarget = False
        self._stop = False
        self._drain_deadline = float("inf")
        self._sock: Optional[socket.socket] = None

    def set_target(self, addr: Tuple[str, int]) -> None:
        with self._cv:
            if addr == self._target:
                return
            self._target = addr
            self._retarget = True
            self._cv.notify_all()

    def post(self, header: dict, payload: bytes = b"") -> None:
        with self._cv:
            self._q.append((header, payload))
            self._cv.notify_all()

    def close(self, drain_s: float = 1.0) -> None:
        with self._cv:
            self._stop = True
            self._drain_deadline = time.monotonic() + drain_s
            self._cv.notify_all()

    def _connect(self) -> Optional[socket.socket]:
        with self._cv:
            target = self._target
        if target is None:
            return None
        try:
            s = socket.create_connection(target, timeout=5.0)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            send_frame(s, {"t": "hello", "frm": self._me})
            for header, payload in self._replay():
                send_frame(s, header, payload)
            _dbg(f"sender[{self._me}] connected -> {target}")
            return s
        except OSError as e:
            _dbg(f"sender[{self._me}] connect {target} failed: {e}")
            return None

    def run(self) -> None:
        backoff = 0.02
        while True:
            with self._cv:
                while not (self._q or self._stop or self._retarget):
                    self._cv.wait(0.2)
                if self._stop and (not self._q
                                   or time.monotonic()
                                   > self._drain_deadline):
                    break
                if self._retarget:
                    self._retarget = False
                    if self._sock is not None:
                        try:
                            self._sock.close()
                        except OSError:
                            pass
                        self._sock = None
                item = self._q[0] if self._q else None
            if self._sock is None:
                self._sock = self._connect()
                if self._sock is None:
                    time.sleep(backoff)
                    backoff = min(backoff * 2, 0.5)
                    continue
                backoff = 0.02
            if item is None:
                continue
            try:
                send_frame(self._sock, item[0], item[1])
                with self._cv:
                    if self._q and self._q[0] is item:
                        self._q.popleft()
            except OSError:
                # successor unreachable: drop the socket, retry (a DEAD
                # gossip will re-target us if it actually died)
                try:
                    self._sock.close()
                except OSError:
                    pass
                self._sock = None
                time.sleep(backoff)
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass


# ---------------------------------------------------------------------------
# Member spec / results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AsyncRingSpec:
    """One ring member's identity + the shared static member table.

    ``peers`` is the same tuple on every member: ((id, host, port), ...) in
    ring order — member i sends to the next *live* entry after it.  The
    convergence-token origin is the first live entry.  ``speculation`` is
    the double-buffer depth: how many rounds a member may run ahead of the
    newest global verdict (2 = compute round t+1 while round t's token
    laps the ring).  ``jax_coordinator`` opts into
    ``jax.distributed.initialize`` for cluster bootstrap (healthy runs;
    see module docstring for why the elastic path keeps it off).
    ``die_after_round`` is fault injection for tests/benchmarks: the
    member hard-exits (process mode) or goes silent (thread mode) after
    posting that round's BN."""
    member_id: int
    peers: Tuple[Tuple[int, str, int], ...]
    max_rounds: int = 16
    speculation: int = 2
    hb_interval_s: float = 0.25
    hb_timeout_s: float = 3.0
    connect_timeout_s: float = 30.0
    wall_limit_s: float = 600.0
    history: int = 6                     # BN replay buffer (> speculation+2)
    jax_coordinator: Optional[str] = None
    die_after_round: Optional[int] = None
    die_hard: bool = False               # True: os._exit(13) (process mode)


def _addr(peers, pid) -> Tuple[str, int]:
    for q, host, port in peers:
        if q == pid:
            return (host, port)
    raise KeyError(pid)


class _MemberState:
    """Everything the receiver/heartbeat/compute threads share."""

    def __init__(self, spec: AsyncRingSpec, edge_masks: np.ndarray):
        ids = [p[0] for p in spec.peers]
        self.mu = threading.RLock()
        self.live: List[int] = list(ids)          # ring order, live only
        self.masks: Dict[int, np.ndarray] = {
            pid: np.asarray(edge_masks[i]).astype(bool)
            for i, pid in enumerate(ids)}
        self.mask_dirty = False                   # my E_i grew (re-partition)
        self.pred_box = Mailbox()
        self.tokens: Dict[int, dict] = {}         # round -> buffered token
        self.verdicts: Dict[int, dict] = {}
        self.last_verdict = -1
        self.best = NEG                           # origin: best before round
        self.want_token = 0                       # origin: next round to lap
        self.injected: set = set()                # rounds whose token we sent
        self.token_sent_at = 0.0
        self.last_seen: Dict[int, float] = {pid: time.monotonic()
                                            for pid in ids}
        self.heard: set = set()                   # peers actually heard from
        self.stop = threading.Event()
        self.stop_rounds: Optional[int] = None
        self.deaths: List[dict] = []              # applied DEAD events (log)
        self.verdict_cv = threading.Condition(self.mu)


def _succ(live: List[int], me: int) -> int:
    i = live.index(me)
    return live[(i + 1) % len(live)]


def _pred(live: List[int], me: int) -> int:
    i = live.index(me)
    return live[(i - 1) % len(live)]


# ---------------------------------------------------------------------------
# The member
# ---------------------------------------------------------------------------

def run_member(
    data: np.ndarray,
    arities: np.ndarray,
    edge_masks: np.ndarray,
    spec: AsyncRingSpec,
    config=None,
    add_limit: Optional[int] = None,
    listen_sock: Optional[socket.socket] = None,
    seen_dead=None,
) -> dict:
    """Run ONE async ring member to convergence; blocking.

    ``edge_masks`` is the full (k, n, n) partition — every member holds all
    subsets so a death can be re-partitioned locally (fold E_v into its
    ring predecessor) with no coordinator.  Returns a dict with the
    member's kept BN (last globally-improving round, exactly the lockstep
    ring's ``g_keep``), its score, executed/committed round counts, the
    final live membership, and per-round phase timings
    ``{"wait_us", "fuse_us", "sweep_us"}`` — ``wait_us`` is the blocked
    wait for the predecessor BN, i.e. the UN-overlapped part of neighbor
    transfer (≈0 when the double buffer is doing its job).
    """
    # jax bootstrap first (must precede backend init), then jax-side imports
    if spec.jax_coordinator is not None:
        import jax

        ids = [p[0] for p in spec.peers]
        jax.distributed.initialize(
            coordinator_address=spec.jax_coordinator,
            num_processes=len(ids),
            process_id=ids.index(spec.member_id),
            initialization_timeout=int(spec.connect_timeout_s))
    import jax.numpy as jnp

    from .ges import GESConfig, ges_jit

    config = config if config is not None else GESConfig()
    me = spec.member_id
    k0, n, _ = np.asarray(edge_masks).shape
    st = _MemberState(spec, edge_masks)
    if seen_dead:                        # deaths known before start (tests)
        for v in seen_dead:
            _apply_dead(st, spec, me, int(v), sender=None)

    data_j = jnp.asarray(np.asarray(data).astype(np.int32))
    ar_j = jnp.asarray(np.asarray(arities).astype(np.int32))
    r_max = int(np.asarray(arities).max())
    # one shared W across members -> all k members reuse one compiled
    # ges_jit program (pid_tables pads to the partition-wide max occupancy)
    shared_w = int(partition.pid_tables(np.asarray(edge_masks)).shape[2])

    hist: Dict[int, np.ndarray] = {}     # round -> own adjacency
    scores: Dict[int, float] = {}
    bn_history: deque = deque(maxlen=spec.history)   # (header, payload)
    hist_mu = threading.Lock()

    def replay():
        with hist_mu:
            return list(bn_history)

    sender = _Sender(me, replay)
    sender.set_target(_addr(spec.peers, _succ(st.live, me)))
    sender.start()

    # ---- inbound -----------------------------------------------------------
    if listen_sock is None:
        listen_sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listen_sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listen_sock.bind(_addr(spec.peers, me))
        listen_sock.listen(8)
    listen_sock.settimeout(0.25)

    def handle(header: dict, payload: bytes) -> None:
        typ = header.get("t")
        frm = header.get("frm", header.get("by", -1))
        with st.mu:
            if frm in st.last_seen:
                st.last_seen[frm] = time.monotonic()
                st.heard.add(frm)
        if typ == "bn":
            adj = np.frombuffer(payload, dtype=np.int8).reshape(n, n)
            st.pred_box.put(int(header["round"]),
                            (adj, float(header["score"]), frm))
        elif typ == "tok":
            _on_token(header)
        elif typ == "ver":
            _on_verdict(header)
        elif typ == "dead":
            _on_dead(header)
        # "hb"/"hello": liveness update above is all they carry

    def reader(conn: socket.socket) -> None:
        f = conn.makefile("rb")
        try:
            while not st.stop.is_set():
                header, payload = recv_frame(f)
                handle(header, payload)
        except (ConnectionError, OSError, ValueError) as e:
            _dbg(f"member[{me}] reader closed: {e!r}")
        except Exception as e:               # a handler bug must be loud
            _dbg(f"member[{me}] reader CRASH: {e!r}")
            raise
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def acceptor() -> None:
        while not st.stop.is_set():
            try:
                conn, _ = listen_sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=reader, args=(conn,), daemon=True).start()

    threading.Thread(target=acceptor, daemon=True).start()

    # ---- control-plane handlers -------------------------------------------
    def _forward(header: dict, payload: bytes = b"") -> None:
        # liveness is credited per direct link: every relayed frame carries
        # the RELAYER as frm (origin/by/victim fields hold the semantics),
        # so hearing a forwarded verdict never vouches for a peer we have
        # no connection from
        h = dict(header)
        h["frm"] = me
        sender.post(h, payload)

    def _is_origin() -> bool:
        with st.mu:
            return st.live[0] == me

    def _emit_verdict(rnd: int, round_best: float) -> None:
        """Origin only: token(rnd) completed a full lap — decide."""
        with st.mu:
            if rnd in st.verdicts:
                return
            improved = round_best > st.best + config.tol
            stop = (not improved) or (rnd + 1 >= spec.max_rounds)
            st.best = max(st.best, round_best)
            ver = {"t": "ver", "frm": me, "origin": me, "round": rnd,
                   "improved": bool(improved), "best": st.best,
                   "stop": bool(stop), "rounds": rnd + 1}
            st.want_token = rnd + 1
            more = len(st.live) > 1
        _apply_verdict(ver)
        if more:
            _forward(ver)
        # the next round may ALREADY be computed (speculation): lap its
        # token immediately instead of waiting for the stale-token timer
        if not ver["stop"] and ver["round"] + 1 in scores:
            _inject_token(ver["round"] + 1)

    def _on_token(tok: dict) -> None:
        rnd = int(tok["round"])
        with st.mu:
            if rnd in st.verdicts:
                return                       # stale (re-injected) lap
            done = rnd in scores
            if not done:
                st.tokens[rnd] = tok         # stamp when we finish rnd
                return
        _stamp_forward(tok)

    def _stamp_forward(tok: dict) -> None:
        rnd = int(tok["round"])
        stamped = set(tok.get("stamped", []))
        rb = float(tok["round_best"])
        if me not in stamped:
            stamped.add(me)
            rb = max(rb, scores[rnd])
        with st.mu:
            missing = [p for p in st.live if p not in stamped]
        if not missing:
            if int(tok["origin"]) == me or _is_origin():
                _emit_verdict(rnd, rb)
            else:                            # origin died mid-lap: hand back
                _forward({"t": "tok", "frm": me, "origin": tok["origin"],
                          "round": rnd, "round_best": rb,
                          "stamped": sorted(stamped)})
            return
        _forward({"t": "tok", "frm": me, "origin": tok["origin"],
                  "round": rnd, "round_best": rb,
                  "stamped": sorted(stamped)})

    def _apply_verdict(ver: dict) -> None:
        rnd = int(ver["round"])
        with st.mu:
            if rnd in st.verdicts:
                return
            st.verdicts[rnd] = ver
            st.last_verdict = max(st.last_verdict, rnd)
            st.verdict_cv.notify_all()
        if ver["improved"] and rnd in hist:
            nonlocal g_report, s_report, committed
            g_report, s_report = hist[rnd], scores[rnd]
            committed = rnd
        for r in [r for r in hist if r <= rnd]:
            hist.pop(r, None)
        st.pred_box.drop_below(rnd - 1)
        if ver["stop"]:
            with st.mu:
                st.stop_rounds = int(ver["rounds"])
            st.stop.set()

    def _on_verdict(ver: dict) -> None:
        rnd = int(ver["round"])
        with st.mu:
            known = rnd in st.verdicts
        _apply_verdict(ver)
        if not known and int(ver["origin"]) != me:
            _forward(dict(ver))              # origin drops its own echo

    def _on_dead(msg: dict) -> None:
        v = int(msg["victim"])
        with st.mu:
            fresh = v in st.live
        if not fresh:
            return                           # gossip completed its cycle
        _apply_dead(st, spec, me, v, sender)
        st.deaths.append({"victim": v, "via": "gossip",
                          "by": int(msg.get("by", -1))})
        if len(st.live) > 1:
            _forward(dict(msg))

    # ---- heartbeat / failure detector -------------------------------------
    def heartbeats() -> None:
        while not st.stop.is_set():
            time.sleep(spec.hb_interval_s)
            sender.post({"t": "hb", "frm": me})
            with st.mu:
                if len(st.live) <= 1:
                    continue
                pred = _pred(st.live, me)
                silent = time.monotonic() - st.last_seen.get(
                    pred, time.monotonic())
                # startup grace: a peer we never heard from gets the full
                # connect window before being declared dead (process-mode
                # members can be seconds apart importing jax)
                limit = (spec.hb_timeout_s if pred in st.heard
                         else max(spec.hb_timeout_s, spec.connect_timeout_s))
            if silent > limit:
                _dbg(f"member[{me}] declares {pred} dead "
                     f"(silent {silent:.1f}s)")
                _apply_dead(st, spec, me, pred, sender)
                st.deaths.append({"victim": pred, "via": "heartbeat",
                                  "by": me})
                with st.mu:
                    more = len(st.live) > 1
                if more:
                    _forward({"t": "dead", "victim": pred, "by": me})
            # origin (possibly newly promoted after a death): re-inject a
            # token that was lost with a dead member
            if _is_origin():
                with st.mu:
                    rnd = max(st.want_token, st.last_verdict + 1)
                    ready = rnd in scores and rnd not in st.verdicts
                    stale = time.monotonic() - st.token_sent_at \
                        > max(4 * spec.hb_timeout_s, 2.0)
                if ready and stale:
                    _inject_token(rnd, force=True)

    def _inject_token(rnd: int, force: bool = False) -> None:
        with st.mu:
            if not force and rnd in st.injected:
                return
            st.injected.add(rnd)
            st.token_sent_at = time.monotonic()
            alone = len(st.live) == 1
        tok = {"t": "tok", "frm": me, "origin": me, "round": rnd,
               "round_best": scores[rnd], "stamped": [me]}
        if alone:
            _emit_verdict(rnd, scores[rnd])
        else:
            _forward(tok)

    threading.Thread(target=heartbeats, daemon=True).start()

    # ---- the compute loop --------------------------------------------------
    g_own = np.zeros((n, n), dtype=np.int8)
    g_report = np.zeros((n, n), dtype=np.int8)
    s_report = NEG
    committed = -1
    member_cache = None
    pid_j = allowed_j = None
    wait_us: List[float] = []
    fuse_us: List[float] = []
    sweep_us: List[float] = []
    evals = 0
    deadline = time.monotonic() + spec.wall_limit_s
    timed_out = False
    rnd = 0

    def _rebuild_tables() -> None:
        nonlocal pid_j, allowed_j
        mask = st.masks[me]
        occ = int(mask.sum(axis=0).max()) if n else 0
        width = max(shared_w, occ, 1) if n else 0
        tbl = partition.pid_table_from_allowed(mask, width=width)
        pid_j = jnp.asarray(tbl)
        allowed_j = jnp.asarray(mask.astype(np.int8))

    _rebuild_tables()
    lim = int(n * n if add_limit is None else add_limit)

    while rnd < spec.max_rounds and not st.stop.is_set():
        # speculation cap: at most `speculation` rounds past newest verdict
        with st.mu:
            while (rnd - st.last_verdict > spec.speculation + 1
                   and not st.stop.is_set()
                   and time.monotonic() < deadline):
                st.verdict_cv.wait(0.05)
        if st.stop.is_set():
            break
        if time.monotonic() > deadline:
            timed_out = True
            break
        with st.mu:
            if st.mask_dirty:
                st.mask_dirty = False
                _rebuild_tables()            # absorbed a dead member's E_v
            alone = len(st.live) == 1

        t0 = time.monotonic()
        if rnd == 0:
            init = np.zeros((n, n), dtype=np.int8)
            wait_us.append(0.0)
            fuse_us.append(0.0)
        else:
            got = st.pred_box.get(rnd - 1, st.stop,
                                  timeout=deadline - time.monotonic())
            t1 = time.monotonic()
            wait_us.append((t1 - t0) * 1e6)
            if got is None:
                if st.stop.is_set():
                    break
                timed_out = True
                break
            g_pred = got[0]
            init = fusion.fusion_edge_union(g_own, g_pred).astype(np.int8)
            fuse_us.append((time.monotonic() - t1) * 1e6)

        t2 = time.monotonic()
        out = ges_jit(data_j, ar_j, jnp.asarray(init), allowed_j,
                      add_limit=lim, config=config, r_max=r_max,
                      pid_table=pid_j, cache=member_cache,
                      return_cache=config.family_cache)
        if config.family_cache:
            adj_j, score_j, n_ins, n_del, member_cache = out
        else:
            adj_j, score_j, n_ins, n_del = out
        g_own = np.asarray(adj_j, dtype=np.int8)
        score = float(score_j)
        w_now = int(pid_j.shape[1])
        evals += w_now * n + w_now * (int(n_ins) + int(n_del))
        sweep_us.append((time.monotonic() - t2) * 1e6)

        hist[rnd] = g_own
        scores[rnd] = score
        header = {"t": "bn", "frm": me, "round": rnd, "score": score}
        payload = g_own.tobytes()
        with hist_mu:
            bn_history.append((header, payload))
        if alone:
            st.pred_box.put(rnd, (g_own, score, me))
        sender.post(header, payload)         # transfer overlaps next round

        # stamp any token that was waiting on this round; origin injects
        with st.mu:
            pending = st.tokens.pop(rnd, None)
        if pending is not None:
            _stamp_forward(pending)
        if _is_origin():
            with st.mu:
                want = st.want_token
            if want == rnd:
                _inject_token(rnd)

        if spec.die_after_round is not None and rnd == spec.die_after_round:
            if spec.die_hard:
                os._exit(13)                 # a real death: no goodbye
            # thread mode: go silent (stop sending, stop answering)
            sender.close(drain_s=0.0)
            st.stop.set()
            try:
                listen_sock.close()
            except OSError:
                pass
            return {"member": me, "died": True, "rounds_executed": rnd + 1}
        rnd += 1

    # drain: wait briefly for the stop verdict if we hit max_rounds first
    if not st.stop.is_set() and not timed_out:
        st.stop.wait(timeout=max(deadline - time.monotonic(), 0.0))
    time.sleep(0.05)                         # let forwarded frames flush
    sender.close()
    st.stop.set()
    try:
        listen_sock.close()
    except OSError:
        pass
    with st.mu:
        rounds = st.stop_rounds if st.stop_rounds is not None else rnd
        live = list(st.live)
        deaths = list(st.deaths)
    return {
        "member": me,
        "adj": g_report,
        "score": s_report,
        "rounds": int(rounds),
        "rounds_executed": int(rnd),
        "committed_round": int(committed),
        "live": live,
        "deaths": deaths,
        "timed_out": timed_out,
        "W": int(pid_j.shape[1]) if pid_j is not None else 0,
        "n_score_evals": int(evals),
        "round_scores": {int(r): float(s) for r, s in sorted(scores.items())},
        "timings": {"wait_us": wait_us, "fuse_us": fuse_us,
                    "sweep_us": sweep_us},
    }


def _apply_dead(st: _MemberState, spec: AsyncRingSpec, me: int, victim: int,
                sender: Optional[_Sender]) -> None:
    """Elastic repair, applied locally by every member: drop the victim
    from the live ring, fold its E_v into its ring predecessor's subset
    (the same rule as partition.remerge_failed), and re-stitch our
    outbound link if our successor changed."""
    with st.mu:
        if victim not in st.live or len(st.live) == 1:
            return
        i = st.live.index(victim)
        absorber = st.live[(i - 1) % len(st.live)]
        st.live.remove(victim)
        st.masks[absorber] = st.masks[absorber] | st.masks[victim]
        if absorber == me:
            st.mask_dirty = True
        succ = _succ(st.live, me)
        # victim may have been holding an unstamped token; clear its slot
        st.last_seen.pop(victim, None)
        # the re-stitch hands us a new predecessor whose last direct frame
        # (if any) may be arbitrarily old — restart its liveness clock and
        # re-grant the first-contact grace so a stale timestamp can't fire
        # the failure detector one tick after the topology change while the
        # new pred is still dialing our listener
        new_pred = _pred(st.live, me)
        st.last_seen[new_pred] = time.monotonic()
        st.heard.discard(new_pred)
    if sender is not None:
        sender.set_target(_addr(spec.peers, succ))


# ---------------------------------------------------------------------------
# In-process threaded mode
# ---------------------------------------------------------------------------

def _free_listeners(k: int):
    socks = []
    for _ in range(k):
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        s.bind(("127.0.0.1", 0))
        s.listen(8)
        socks.append(s)
    return socks


def run_ring_async_threads(
    data: np.ndarray,
    arities: np.ndarray,
    edge_masks: np.ndarray,
    config=None,
    add_limit: Optional[int] = None,
    max_rounds: int = 16,
    speculation: int = 2,
    die_member: Optional[int] = None,
    die_after_round: Optional[int] = None,
    hb_timeout_s: float = 2.0,
    wall_limit_s: float = 300.0,
) -> dict:
    """The async ring with k members as THREADS of this process, exchanging
    over localhost sockets — the same :func:`run_member` code path the
    multi-process launcher runs, minus process isolation (ges_jit
    compilations are shared, so this is also the cheap mode for tests and
    benchmarks).  ``die_member``/``die_after_round`` inject a silent
    failure to exercise the elastic path.  Returns per-member results plus
    the lockstep-comparable aggregate (graphs/scores in ring order of the
    surviving members, executed round count, and summed phase timings).
    """
    k = int(np.asarray(edge_masks).shape[0])
    socks = _free_listeners(k)
    peers = tuple((i, "127.0.0.1", s.getsockname()[1])
                  for i, s in enumerate(socks))
    results: Dict[int, dict] = {}
    errors: List[BaseException] = []

    def runner(i: int) -> None:
        spec = AsyncRingSpec(
            member_id=i, peers=peers, max_rounds=max_rounds,
            speculation=speculation, hb_timeout_s=hb_timeout_s,
            wall_limit_s=wall_limit_s,
            die_after_round=(die_after_round if i == die_member else None),
            die_hard=False)
        try:
            results[i] = run_member(data, arities, edge_masks, spec,
                                    config=config, add_limit=add_limit,
                                    listen_sock=socks[i])
        except BaseException as e:          # surface thread crashes
            errors.append(e)

    threads = [threading.Thread(target=runner, args=(i,), daemon=True)
               for i in range(k)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=wall_limit_s + 30.0)
    if errors:
        raise errors[0]
    survivors = [i for i in range(k)
                 if i in results and not results[i].get("died")]
    if not survivors:
        raise RuntimeError("async ring: no surviving members reported")
    rep = results[survivors[0]]
    agg = {
        "graphs": np.stack([results[i]["adj"] for i in survivors]),
        "scores": np.array([results[i]["score"] for i in survivors]),
        "rounds": int(max(results[i]["rounds"] for i in survivors)),
        "live": rep["live"],
        "members": results,
        "survivors": survivors,
        "timed_out": any(results[i]["timed_out"] for i in survivors),
    }
    agg["best_member"] = survivors[int(np.argmax(agg["scores"]))]
    agg["best_adj"] = results[agg["best_member"]]["adj"]
    agg["best_score"] = float(agg["scores"].max())
    agg["n_score_evals"] = int(sum(results[i].get("n_score_evals", 0)
                                   for i in results))
    # lockstep-comparable per-round trace: max over surviving members of the
    # score each posted for round r (only rounds the verdict protocol counted)
    agg["ring_scores"] = [
        max(results[i]["round_scores"][r] for i in survivors
            if r in results[i]["round_scores"])
        for r in range(agg["rounds"])
        if any(r in results[i]["round_scores"] for i in survivors)]
    # phase totals over surviving members (per-member lists kept too)
    agg["phase_us"] = {
        ph: {str(i): float(np.sum(results[i]["timings"][ph]))
             for i in survivors}
        for ph in ("wait_us", "fuse_us", "sweep_us")}
    return agg
