"""GES — Greedy Equivalence Search (greedy-FES variant of Alonso-Barba et al.
2013, the exact variant the cGES paper uses as its local learner; see paper
§2.2) with the BES stage intact.

Search is performed in DAG space with the score-equivalent BDeu metric:
* FES: repeatedly apply the best positive single-edge insertion.
* BES: repeatedly apply the best positive single-edge deletion.

Both stages can be restricted to an ``allowed`` edge mask (the E_i subsets of
cGES) and FES can be capped at ``add_limit`` insertions (cGES-L).

Two drivers with identical greedy trajectories:

* :func:`ges_host` — Python loop + jitted *column* rescoring (the incremental
  trick: after touching child y only column y of the delta cache changes).
  This is the "parallel GES" control algorithm of the paper — the candidate
  sweep is the parallel part, here a single batched tensor op.
* :func:`ges_jit` — the whole FES+BES search as one jit-compiled
  ``lax.while_loop`` program (fixed shapes), used inside the shard_map ring.

All candidate rescoring — FES insert columns, BES delete columns, restricted
E_i subsets, full delta matrices — goes through the unified engine in
:mod:`repro.core.sweeps` (``sweep(kind="insert"|"delete", pids=...)``), which
dispatches to the loop / fused-jnp / fused-Pallas backend named by
``GESConfig.counts_impl``.

Both drivers pay W-wide restricted sweeps when given the E_i candidate
table: :func:`ges_host` gathers each column down to its ``pids`` subset, and
:func:`ges_jit` threads a static (n, W) ``pid_table`` through its whole
``lax.while_loop`` program — delta state, argmax, apply and incremental
rescoring all live in (W, n) index space, so the compiled ring's per-round
cost tracks W = |E_i|, not n (the paper's core cost argument, end-to-end
compiled).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import zlib
from functools import lru_cache, partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import bdeu, score_cache
from .dag import closure_after_edge, transitive_closure, transitive_closure_np
from .partition import pid_table_from_allowed
from .sweeps import (DATA_AXIS, KIND_CODES, _data_mesh, pad_data_rows,
                     shard_map_compat, sweep, sweep_column_body,
                     sweep_column_cached, sweep_matrix_body,
                     sweep_matrix_restricted_body)

Array = jax.Array
NEG_INF = -jnp.inf


def _env_flag(name: str) -> bool:
    return os.environ.get(name, "0").lower() in ("1", "true", "yes", "on")


@dataclasses.dataclass(frozen=True)
class GESConfig:
    ess: float = 10.0
    max_parents: int = 6          # static parent-set bound for the device engine
    max_q: int = 4096             # dense contingency-table row bound
    # per-family loop engines: "segment" | "onehot" | "pallas";
    # fused sweep engines (insert: one contraction per child; delete: one
    # family-table build per child — not n either way):
    # "fused" (jnp) | "fused_pallas" (kernels/bdeu_sweep + bdeu_count).
    # The default honours REPRO_COUNTS_IMPL so CI can run the whole tier-1
    # suite under an alternate backend (the fused CI legs).  default_factory,
    # not a plain default: a dataclass default is bound once at class
    # creation, which would silently ignore the env var whenever it is set
    # after ``import repro`` (regression-tested).
    counts_impl: str = dataclasses.field(
        default_factory=lambda: os.environ.get("REPRO_COUNTS_IMPL", "segment"))
    tol: float = 1e-9             # minimum improvement to keep going
    incremental: bool = True      # column-cached delta rescoring
    child_chunk: Optional[int] = None  # sequential chunking of full sweeps
    # Data-axis sharding for the HOST driver's sweeps: shard the instance
    # axis over this many devices (sweeps.sweep(data_shards=...)); results
    # are table-identical to 1 (regression-tested).  The compiled ring takes
    # its data axis from RingSpec instead (2-D ring x data mesh).
    data_shards: int = 1
    # Persistent device-resident family-score cache (core/score_cache):
    # memoises masked score columns across GES iterations, rounds and ring
    # members with prioritized eviction; trajectories stay bitwise-identical
    # to uncached.  Env-defaulted like counts_impl (read at call time) so a
    # CI leg can flip the whole suite with REPRO_FAMILY_CACHE=1.
    family_cache: bool = dataclasses.field(
        default_factory=lambda: _env_flag("REPRO_FAMILY_CACHE"))
    cache_capacity: int = 1024    # slots (columns) in the family-score cache

    def __post_init__(self):
        # Fail loudly on unknown backends: the dispatch chains fall through
        # to "segment", so a typo (config or REPRO_COUNTS_IMPL) would
        # otherwise silently run the wrong engine.
        bdeu.check_counts_impl(self.counts_impl)
        if self.data_shards < 1:
            raise ValueError(f"data_shards must be >= 1, got {self.data_shards}")
        if self.cache_capacity < 1:
            raise ValueError(
                f"cache_capacity must be >= 1, got {self.cache_capacity}")

    def static_key(self):
        return (self.ess, self.max_parents, self.max_q, self.counts_impl,
                self.tol, self.incremental, self.child_chunk,
                self.data_shards, self.family_cache, self.cache_capacity)


# ---------------------------------------------------------------------------
# Column-level delta rescoring — all of it goes through core/sweeps.sweep:
# one API, kind="insert"|"delete", optional pids restriction, engine-masked
# columns identical under the loop and fused backends.
# ---------------------------------------------------------------------------

def _q_guard_np(adj: np.ndarray, arities: np.ndarray, max_q: int) -> np.ndarray:
    """Boolean (n, n) matrix: True where adding x->y keeps q_y <= max_q."""
    log_r = np.log(arities.astype(np.float64))
    log_q = adj.astype(np.float64).T @ log_r  # (n,) current log q per child
    return (log_q[None, :] + log_r[:, None]) <= np.log(max_q) + 1e-9


# ---------------------------------------------------------------------------
# Host driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GESResult:
    adj: np.ndarray
    score: float
    n_inserts: int
    n_deletes: int
    n_score_evals: int   # machine-independent cost counter (paper's CPU-time proxy)


# Device-resident per-dataset arrays, cached across rounds: the host driver
# used to re-upload the (m, n) code array (and rebuild every derived one-hot
# from scratch on device) in EVERY ges_host call, although cges/ring_rounds
# call it with the same dataset dozens of times.  Content-addressed (sha1 of
# the bytes), so id-reuse can never alias two datasets; small and bounded.
_DEVICE_DATA_CACHE: dict = {}
_DEVICE_DATA_CAP = 8


def device_data(data: np.ndarray, arities: np.ndarray):
    """(data_j, ar_j) int32 device arrays for a host dataset, cached by
    content so repeated ges_host calls (cges rounds, ring driving) reuse the
    resident copies instead of re-transferring per call."""
    key = (hashlib.sha1(np.ascontiguousarray(data).tobytes()).digest(),
           hashlib.sha1(np.ascontiguousarray(arities).tobytes()).digest(),
           data.shape)
    hit = _DEVICE_DATA_CACHE.get(key)
    if hit is None:
        if len(_DEVICE_DATA_CACHE) >= _DEVICE_DATA_CAP:
            _DEVICE_DATA_CACHE.clear()
        hit = (jnp.asarray(data.astype(np.int32)),
               jnp.asarray(arities.astype(np.int32)))
        _DEVICE_DATA_CACHE[key] = hit
    return hit


class DeviceFamilyCache:
    """Mutable host handle to a device-resident family-score cache
    (:mod:`repro.core.score_cache`) for the HOST driver.

    Columns are cached in full-n scattered form (width n, -inf outside the
    restriction), so ONE handle is shared across cGES members with different
    E_i widths, across rounds, and by the unrestricted fine-tune; the scope
    word (crc32 of the allowed column) keeps differently-restricted columns
    from aliasing.  ``state`` is an immutable pytree — ges_host replaces it
    after every probe/insert, which is what makes the cache persist across
    calls.
    """

    def __init__(self, n_vars: int, capacity: int = 1024):
        self.n_vars = int(n_vars)
        self.state = score_cache.init(n_vars, n_vars, capacity)

    def stats(self) -> dict:
        return score_cache.stats(self.state)


def _scope_word(allowed_col: np.ndarray) -> int:
    """int32 scope for one column's allowed-candidate subset (crc32)."""
    v = zlib.crc32(np.ascontiguousarray(allowed_col).tobytes())
    return v - (1 << 32) if v >= (1 << 31) else v


class ScoreCache:
    """Cross-call delta-column cache — the host mirror of the paper's
    'concurrent safe data structure' that all ring processes share.

    Keyed by (kind, child, parent-set bytes); each hit saves n local-score
    evaluations.  A single instance is shared by all cGES processes across
    all ring rounds.
    """

    def __init__(self):
        self._store: dict = {}
        self.hits = 0
        self.misses = 0

    def column(self, kind: str, y: int, adj: np.ndarray, compute,
               scope: bytes = b"") -> np.ndarray:
        """``scope`` must identify the allowed-candidate subset the column
        was computed under (columns are -inf outside it): processes with
        different E_i may NOT share entries, or a restricted column would
        leak into another process / the unrestricted fine-tune."""
        key = (kind, y, scope, adj[:, y].tobytes())
        col = self._store.get(key)
        if col is None:
            self.misses += 1
            col = compute()
            self._store[key] = col
        else:
            self.hits += 1
        return col


def ges_host(
    data: np.ndarray,
    arities: np.ndarray,
    init_adj: Optional[np.ndarray] = None,
    allowed: Optional[np.ndarray] = None,
    add_limit: Optional[int] = None,
    config: Optional[GESConfig] = None,
    phases: str = "both",            # "fes" | "bes" | "both"
    cache: Optional[ScoreCache] = None,
    family_cache: Optional[DeviceFamilyCache] = None,
) -> GESResult:
    """Greedy FES+BES on host with jit-batched column rescoring.

    ``family_cache``: optional shared :class:`DeviceFamilyCache` — the
    device-resident persistent column cache (auto-created per call when
    ``config.family_cache`` is set and none is passed; cges passes one
    handle so entries persist across members, rounds and the fine-tune).
    It REPLACES the host-dict ``cache`` layer when present (both are exact
    and keyed identically — stacking them would starve the device cache).
    ``config.data_shards > 1`` shards every sweep's instance axis
    (sweeps.sweep(data_shards=...)); both knobs leave trajectories
    bitwise-identical.
    """
    m, n = data.shape
    # built per call, not bound at import — honours REPRO_COUNTS_IMPL set
    # after ``import repro`` (see GESConfig.counts_impl)
    cfg = config if config is not None else GESConfig()
    r_max = int(arities.max())
    adj = (np.zeros((n, n), dtype=np.int8) if init_adj is None
           else init_adj.astype(np.int8).copy())
    allowed_np = (np.ones((n, n), dtype=bool) if allowed is None
                  else allowed.astype(bool))
    np.fill_diagonal(allowed_np, False)

    data_j, ar_j = device_data(data, arities)
    if family_cache is None and cfg.family_cache:
        family_cache = DeviceFamilyCache(n, cfg.cache_capacity)
    if family_cache is not None and family_cache.n_vars != n:
        raise ValueError(
            f"family_cache was built for n={family_cache.n_vars} variables, "
            f"got a {n}-variable problem")
    scope_words = [_scope_word(allowed_np[:, y]) for y in range(n)]

    evals = 0

    # Restricted-subset column scoring: each column y only evaluates its
    # allowed candidates (W = max column occupancy of E_i, padded for static
    # jit shapes).  This is where the ring's speedup physically comes from —
    # a process pays |E_i|/n per column, not n.
    allowed_cost = allowed_np.sum(axis=0)
    pid_table = pid_table_from_allowed(allowed_np)
    pid_j = jnp.asarray(pid_table)

    def _scatter(y, vals):
        col = np.full(n, -np.inf)
        ids = pid_table[y]
        col[ids] = np.asarray(vals)
        col[y] = -np.inf                     # self-pad stays invalid
        return col

    def _col(kind, cache_key, a, y, n_evals):
        nonlocal evals

        def compute():
            nonlocal evals
            evals += n_evals
            vals = sweep(data_j, ar_j, jnp.asarray(a), kind=kind, y=y,
                         pids=pid_j[y], ess=cfg.ess, max_q=cfg.max_q,
                         r_max=r_max, counts_impl=cfg.counts_impl,
                         data_shards=cfg.data_shards)
            return _scatter(y, vals)

        def compute_device_cached():
            # Persistent device cache: probe answers hit/miss (refreshing
            # recency on device); only a miss pays the sweep, whose column
            # is then inserted with prioritized eviction.  The key is exact
            # (kind, y, parents, scope=crc32(allowed column)), so the
            # returned column is bitwise the one compute() would produce.
            fc = family_cache
            code = KIND_CODES[kind]
            pm = jnp.asarray(a[:, y] > 0)
            hit, col, fc.state = score_cache._probe_jit(
                fc.state, code, jnp.int32(y), pm, jnp.int32(scope_words[y]))
            if bool(hit):
                return np.asarray(col, dtype=np.float64)
            res = compute()
            fc.state = score_cache._insert_jit(
                fc.state, code, jnp.int32(y), pm, jnp.int32(scope_words[y]),
                jnp.asarray(res, dtype=jnp.float32))
            return res

        # The device cache REPLACES the host-dict layer (both are exact and
        # keyed identically, so a dict in front would absorb every hit and
        # the bounded device-resident cache would only ever see first-time
        # keys); either layer alone leaves trajectories identical.
        if family_cache is not None:
            return compute_device_cached()
        if cache is not None:
            return cache.column(cache_key, y, a, compute,
                                scope=allowed_np[:, y].tobytes())
        return compute()

    def ins_col(a, y):
        return _col("insert", "ins", a, y, int(allowed_cost[y]))

    def del_col(a, y):
        return _col("delete", "del", a, y,
                    int(np.sum(allowed_np[:, y] & (a[:, y] > 0))))

    n_ins = 0
    n_del = 0
    # Partition-restricted sweeps (the ring's whole point): a process whose
    # E_i excludes column y never scores it — the vectorized sweep mirrors
    # the paper's task pool by skipping empty columns outright.
    col_allowed = allowed_np.any(axis=0)
    NEG = np.full(n, -np.inf)

    # ---------------- FES ----------------
    if phases in ("fes", "both"):
        reach = transitive_closure_np(adj.astype(bool))
        D = np.stack([ins_col(adj, y) if col_allowed[y] else NEG
                      for y in range(n)], axis=1)            # (x, y)
        while True:
            if add_limit is not None and n_ins >= add_limit:
                break
            pa_count = adj.sum(axis=0)
            valid = (allowed_np & ~adj.astype(bool) & ~reach.T
                     & (pa_count[None, :] < cfg.max_parents)
                     & _q_guard_np(adj, arities, cfg.max_q))
            masked = np.where(valid, D, -np.inf)
            x, y = np.unravel_index(np.argmax(masked), masked.shape)
            if not np.isfinite(masked[x, y]) or masked[x, y] <= cfg.tol:
                break
            adj[x, y] = 1
            reach = closure_after_edge(reach, int(x), int(y))
            n_ins += 1
            D[:, y] = ins_col(adj, y)

    # ---------------- BES ----------------
    if phases in ("bes", "both"):
        del_cols = (adj.astype(bool) & allowed_np).any(axis=0)
        D = np.stack([del_col(adj, y) if del_cols[y] else NEG
                      for y in range(n)], axis=1)
        while True:
            valid = adj.astype(bool) & allowed_np
            masked = np.where(valid, D, -np.inf)
            x, y = np.unravel_index(np.argmax(masked), masked.shape)
            if not np.isfinite(masked[x, y]) or masked[x, y] <= cfg.tol:
                break
            adj[x, y] = 0
            n_del += 1
            D[:, y] = del_col(adj, y)

    score = bdeu.graph_score_np(data, arities, adj, cfg.ess)
    return GESResult(adj=adj, score=score, n_inserts=n_ins, n_deletes=n_del,
                     n_score_evals=evals)


# ---------------------------------------------------------------------------
# Fully-jitted driver (device engine, used inside the shard_map ring)
# ---------------------------------------------------------------------------

def _masked_argmax(mat: Array):
    """Return (flat_idx, value) of the max over a (n, n) matrix."""
    flat = mat.reshape(-1)
    idx = jnp.argmax(flat)
    return idx, flat[idx]


def _masked_argmax_mapped(mat: Array, key: Array, n: int):
    """Argmax over a (W, n) restricted matrix with FULL-N tie-breaking.

    ``key[w, y] = x*n + y`` is each entry's flat index in the (n, n) space.
    BDeu is score-equivalent, so exact delta ties (x -> y vs y -> x) are
    common, and jnp.argmax's first-maximum rule resolves them by position —
    which differs between (w, y) and (x, y) layouts.  Taking the minimum
    full-n key among the maxima reproduces the full-n path's tie-break
    exactly, which is what makes restricted and full-n-masked compiled
    trajectories identical (asserted by tests).
    """
    best = jnp.max(mat)
    idx = jnp.min(jnp.where(mat == best, key, jnp.int32(n * n)))
    return jnp.minimum(idx, jnp.int32(n * n - 1)), best


@partial(jax.jit, static_argnames=(
    "ess", "max_parents", "max_q", "r_max", "counts_impl", "tol", "incremental",
    "child_chunk"))
def _ges_jit_impl(data, arities, init_adj, allowed, add_limit, pid_table,
                  ess, max_parents, max_q, r_max, counts_impl, tol,
                  incremental, child_chunk, cache, cache_scope):
    return ges_jit_body(data, arities, init_adj, allowed, add_limit,
                        ess, max_parents, max_q, r_max, counts_impl, tol,
                        incremental, child_chunk, pid_table=pid_table,
                        cache=cache, cache_scope=cache_scope)


def ges_jit_body(data, arities, init_adj, allowed, add_limit,
                 ess, max_parents, max_q, r_max, counts_impl, tol,
                 incremental, child_chunk=None,
                 axis_model=None, axis_model_size: int = 1,
                 pid_table=None, data_axis_name=None,
                 cache=None, cache_scope=0):
    """Traceable (un-jitted) GES program — callable from inside shard_map.

    ``axis_model``: optional mesh axis over which the full candidate sweeps
    are split (scoring-TP inside a ring process; see bdeu._deltas_impl).

    ``pid_table``: optional static (n, W) candidate table (the ring's E_i,
    self-padded; see partition.pid_table_from_allowed).  When given, the
    ENTIRE program — the FES/BES initialization matrices, the while_loop's
    argmax/apply steps and the incremental column rescoring — runs in
    (W, n) index space: delta state is (W, n), winners map back through the
    table as ``x = pid_table[y, w]``, and every sweep pays W-wide cost.
    This is what makes the compiled ring's per-round cost track W = |E_i|
    instead of n.  ``pid_table=None`` keeps the full-n (n, n) path (the
    unrestricted fine-tune / plain-GES case).

    ``data_axis_name``: optional SECOND mesh axis sharding the instance (m)
    axis — every count build contracts the local m/d shard and psums (see
    core/sweeps, "Two ORTHOGONAL mesh axes").  The caller owns padding
    ragged m with sentinel rows (sweeps.pad_data_rows).

    ``cache``/``cache_scope``: optional persistent family-score cache state
    (score_cache.FamilyScoreCache, column width W if restricted else n).
    The FES/BES init matrices are then built column-by-column through the
    cache (lax.scan) and the incremental rescoring consults it inside the
    while_loop carries; the returned tuple gains the final cache state
    (5-tuple instead of 4).  Under a data axis the cache state is replicated
    across data-axis devices (identical psum'd columns -> identical
    evolution), so the hit/miss cond never diverges.
    """
    n = init_adj.shape[0]
    use_cache = cache is not None
    eye = jnp.eye(n, dtype=bool)
    allowed = allowed.astype(bool) & ~eye
    log_r = jnp.log(arities.astype(jnp.float32))
    log_max_q = jnp.log(jnp.float32(max_q)) + 1e-6
    restricted = pid_table is not None
    if restricted:
        x_of = pid_table.T                        # (W, n): x_of[w, y] = x
        ycols = jnp.arange(n, dtype=jnp.int32)[None, :]
        pid_key = x_of.astype(jnp.int32) * n + ycols   # full-n flat indices

        def gather_wy(mat):
            """(n, n) mask/matrix -> (W, n) entries at [pid_table[y, w], y]."""
            return mat[x_of, ycols]

    def full_insert_D(adj):
        if restricted:
            return sweep_matrix_restricted_body(
                data, arities, adj, pid_table, ess, max_q, r_max,
                counts_impl, "insert", child_chunk,
                axis_name=axis_model, axis_size=axis_model_size,
                data_axis_name=data_axis_name)
        return sweep_matrix_body(data, arities, adj, ess, max_q, r_max,
                                 counts_impl, "insert", child_chunk,
                                 axis_name=axis_model,
                                 axis_size=axis_model_size,
                                 data_axis_name=data_axis_name)

    def full_delete_D(adj):
        if restricted:
            return sweep_matrix_restricted_body(
                data, arities, adj, pid_table, ess, max_q, r_max,
                counts_impl, "delete", child_chunk,
                axis_name=axis_model, axis_size=axis_model_size,
                data_axis_name=data_axis_name)
        return sweep_matrix_body(data, arities, adj, ess, max_q, r_max,
                                 counts_impl, "delete", child_chunk,
                                 axis_name=axis_model,
                                 axis_size=axis_model_size,
                                 data_axis_name=data_axis_name)

    def ins_col(adj, y):
        pids = pid_table[y] if restricted else None
        return sweep_column_body(data, arities, adj, y, pids, ess, max_q,
                                 r_max, counts_impl, "insert",
                                 data_axis_name=data_axis_name)

    def del_col(adj, y):
        pids = pid_table[y] if restricted else None
        return sweep_column_body(data, arities, adj, y, pids, ess, max_q,
                                 r_max, counts_impl, "delete",
                                 data_axis_name=data_axis_name)

    def col_cached(c, adj, y, kind):
        pids = pid_table[y] if restricted else None
        return sweep_column_cached(c, data, arities, adj, y, pids, ess,
                                   max_q, r_max, counts_impl, kind,
                                   scope=cache_scope,
                                   data_axis_name=data_axis_name)

    def cached_D(c, adj, kind):
        """Init matrix built column-by-column THROUGH the cache (lax.scan
        threads the cache state): a round whose graph already has column y's
        family cached skips that column's whole contraction.  Mirrors the
        uncached matrix bodies' child split under ``axis_model``."""
        ids = jnp.arange(n, dtype=jnp.int32)
        if axis_model is not None:
            per = -(-n // axis_model_size)
            i = jax.lax.axis_index(axis_model)
            ids = jnp.clip(i * per + jnp.arange(per), 0, n - 1).astype(
                jnp.int32)

        def scan_body(c, y):
            col, c = col_cached(c, adj, y, kind)
            return c, col

        c, cols = jax.lax.scan(scan_body, c, ids)            # (cnt, V)
        if axis_model is not None:
            cols = jax.lax.all_gather(cols, axis_model, axis=0,
                                      tiled=True)[:n]
        return cols.T, c

    # ---------------- FES ----------------
    def fes_cond(state):
        return ~state[4]

    def fes_body(state):
        adj, reach, D, n_ins, done = state[:5]
        c = state[5] if use_cache else None
        pa_count = adj.sum(axis=0)
        log_q = adj.astype(jnp.float32).T @ log_r
        if restricted:
            # same validity predicate as the full-n path, gathered into the
            # (W, n) index space: entry [w, y] tests x = pid_table[y, w] -> y
            valid = (gather_wy(allowed & ~adj.astype(bool))
                     & ~reach[ycols, x_of]          # == (~reach.T)[x, y]
                     & (pa_count[None, :] < max_parents)
                     & ((log_q[None, :] + log_r[x_of]) <= log_max_q))
        else:
            q_ok = (log_q[None, :] + log_r[:, None]) <= log_max_q
            valid = (allowed & ~adj.astype(bool) & ~reach.T
                     & (pa_count[None, :] < max_parents) & q_ok)
        masked = jnp.where(valid, D, NEG_INF)
        idx, best = (_masked_argmax_mapped(masked, pid_key, n) if restricted
                     else _masked_argmax(masked))
        x, y = idx // n, idx % n
        do_apply = (best > tol) & (n_ins < add_limit)

        new_adj = adj.at[x, y].set(jnp.where(do_apply, 1, adj[x, y]))
        new_reach = jnp.where(do_apply, closure_after_edge(reach, x, y), reach)
        if incremental:
            if use_cache:
                new_col, c = col_cached(c, new_adj, y, "insert")
            else:
                new_col = ins_col(new_adj, y)
            new_D = jnp.where(do_apply, D.at[:, y].set(new_col), D)
        else:
            if use_cache:
                full_D, c = cached_D(c, new_adj, "insert")
            else:
                full_D = full_insert_D(new_adj)
            new_D = jnp.where(do_apply, full_D, D)
        out = (new_adj, new_reach, new_D,
               n_ins + do_apply.astype(jnp.int32), ~do_apply)
        return out + (c,) if use_cache else out

    adj0 = init_adj.astype(jnp.int8)
    reach0 = transitive_closure(adj0.astype(bool))
    if use_cache:
        D0, cache = cached_D(cache, adj0, "insert")
    else:
        D0 = full_insert_D(adj0)
    state = (adj0, reach0, D0, jnp.int32(0), jnp.bool_(False))
    if use_cache:
        state = state + (cache,)
    fes_out = jax.lax.while_loop(fes_cond, fes_body, state)
    adj1, n_ins = fes_out[0], fes_out[3]
    if use_cache:
        cache = fes_out[5]

    # ---------------- BES ----------------
    def bes_cond(state):
        return ~state[3]

    def bes_body(state):
        adj, D, n_del, done = state[:4]
        c = state[4] if use_cache else None
        valid = adj.astype(bool) & allowed
        if restricted:
            valid = gather_wy(valid)
        masked = jnp.where(valid, D, NEG_INF)
        idx, best = (_masked_argmax_mapped(masked, pid_key, n) if restricted
                     else _masked_argmax(masked))
        x, y = idx // n, idx % n
        do_apply = best > tol
        new_adj = adj.at[x, y].set(jnp.where(do_apply, 0, adj[x, y]))
        if incremental:
            if use_cache:
                new_col, c = col_cached(c, new_adj, y, "delete")
            else:
                new_col = del_col(new_adj, y)
            new_D = jnp.where(do_apply, D.at[:, y].set(new_col), D)
        else:
            if use_cache:
                full_D, c = cached_D(c, new_adj, "delete")
            else:
                full_D = full_delete_D(new_adj)
            new_D = jnp.where(do_apply, full_D, D)
        out = (new_adj, new_D, n_del + do_apply.astype(jnp.int32), ~do_apply)
        return out + (c,) if use_cache else out

    if use_cache:
        D1, cache = cached_D(cache, adj1, "delete")
    else:
        D1 = full_delete_D(adj1)
    state = (adj1, D1, jnp.int32(0), jnp.bool_(False))
    if use_cache:
        state = state + (cache,)
    bes_out = jax.lax.while_loop(bes_cond, bes_body, state)
    adj2, n_del = bes_out[0], bes_out[2]

    score = bdeu.graph_score_jax(data, arities, adj2, ess, max_q, r_max,
                                 counts_impl, data_axis_name=data_axis_name)
    if use_cache:
        return adj2, score, n_ins, n_del, bes_out[4]
    return adj2, score, n_ins, n_del


@lru_cache(maxsize=None)
def _sharded_ges_prog(d, ess, max_parents, max_q, r_max, counts_impl, tol,
                      incremental, child_chunk):
    """Compiled full-GES program over a d-device data-axis mesh: the whole
    ges_jit_body runs under shard_map with the (m, n) rows sharded
    P("data") and everything else (graphs, pid table, cache state)
    replicated, so every count build contracts m/d rows and psums.  All
    outputs are data-axis-replicated (psum'd scores, lockstep cache), hence
    the blanket ``P()`` out_spec.  Optional pid_table/cache arguments pass
    through as pytrees (None == empty pytree), so one cache entry serves
    all four present/absent combinations per static config."""
    mesh = _data_mesh(d)

    def body(data, arities, init_adj, allowed, add_limit, pid_table, cache):
        return ges_jit_body(data, arities, init_adj, allowed, add_limit,
                            ess, max_parents, max_q, r_max, counts_impl,
                            tol, incremental, child_chunk,
                            pid_table=pid_table, data_axis_name=DATA_AXIS,
                            cache=cache)

    return jax.jit(shard_map_compat(
        body, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(DATA_AXIS),) +
                 (jax.sharding.PartitionSpec(),) * 6,
        out_specs=jax.sharding.PartitionSpec()))


def ges_jit(
    data: Array,
    arities: Array,
    init_adj: Array,
    allowed: Array,
    add_limit: Optional[int] = None,
    config: Optional[GESConfig] = None,
    r_max: Optional[int] = None,
    pid_table: Optional[Array] = None,
    cache: Optional[score_cache.FamilyScoreCache] = None,
    return_cache: bool = False,
):
    """Fully-compiled GES. ``add_limit=None`` means unlimited (n^2 cap).

    ``pid_table``: optional (n, W) restricted candidate table — the compiled
    program then sweeps W-wide end-to-end (see ges_jit_body).  The table must
    cover ``allowed`` column-for-column (partition.pid_table_from_allowed
    builds it); candidates absent from the table are never scored.

    ``cache``: optional persistent family-score cache state carried across
    calls (auto-created when ``config.family_cache`` and omitted).  Pass
    ``return_cache=True`` to receive ``(adj, score, n_ins, n_del, cache')``
    so the warmed state can seed the next round; the cached trajectory is
    bitwise-identical to the uncached one (exact keys — see core/score_cache).
    """
    config = config if config is not None else GESConfig()
    n = init_adj.shape[0]
    lim = jnp.int32(n * n if add_limit is None else add_limit)
    if r_max is None:
        r_max = int(np.asarray(arities).max())
    if cache is None and config.family_cache:
        width = int(pid_table.shape[1]) if pid_table is not None else n
        cache = score_cache.init(n, width, config.cache_capacity)
    if config.data_shards > 1:
        d = config.data_shards
        prog = _sharded_ges_prog(
            d, config.ess, config.max_parents, config.max_q, r_max,
            config.counts_impl, config.tol, config.incremental,
            config.child_chunk)
        out = prog(pad_data_rows(jnp.asarray(data), r_max, d),
                   jnp.asarray(arities), jnp.asarray(init_adj),
                   jnp.asarray(allowed), lim, pid_table, cache)
    else:
        out = _ges_jit_impl(
            data, arities, init_adj, allowed, lim, pid_table,
            config.ess, config.max_parents, config.max_q, r_max,
            config.counts_impl, config.tol, config.incremental,
            config.child_chunk, cache, jnp.int32(0))
    if cache is not None and not return_cache:
        return out[:4]
    return out
