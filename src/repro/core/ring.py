"""The ring executor — cGES's learning stage as ONE compiled multi-device
program (shard_map over a "ring" mesh axis).

Mapping of the paper's distributed system onto JAX:

  * k ring processes        ->  k devices (or device groups) on a mesh axis
  * "send BN to successor"  ->  jax.lax.ppermute of the (n, n) int8 adjacency
  * BN fusion               ->  core/fusion.fuse_trace: the traceable engine
                                of the UNIFIED fusion layer (GHO ordering +
                                covered-edge-reversal sink conversion, one
                                maintained longest-path depth vector,
                                vmap-batched sigma transforms) — the same
                                code the host driver dispatches to, not a
                                hand-mirrored copy; this module keeps no
                                fusion math of its own (only re-exports)
  * constrained GES         ->  ges.ges_jit_body (lax.while_loop program);
                                every candidate rescoring inside it — FES
                                insert and BES delete columns alike — goes
                                through the unified core/sweeps engine, so a
                                fused counts_impl fuses BOTH phases of every
                                ring process (insert: one contraction per
                                column; delete: one family-table build per
                                column, marginalized per parent slot)
  * restricted E_i sweeps   ->  a static per-process (n, W) pid_table
                                (partition.pid_tables) rides the ring axis
                                next to the edge masks; ges_jit_body then
                                runs its whole while_loop in (W, n) index
                                space, so each compiled process pays
                                W = |E_i|-wide sweeps per round — the
                                paper's cost argument, end-to-end compiled
                                (restricted=False keeps the old
                                full-n-sweep-then-mask program)
  * convergence check       ->  lax.pmax over per-device best scores

The entire learning stage — all rounds, all k processes — is a single
jit-compiled program; one host call runs cGES's stage 2 to convergence.
This is also the program that is `.lower().compile()`d on the production
(16, 16) and (2, 16, 16) meshes by launch/dryrun.py (arch id: ``cges_ring``).

This lockstep program is the TRAJECTORY ORACLE: every round is a global
barrier (ppermute -> fuse -> sweep -> pmax), which makes it bitwise
reproducible but also means neighbor transfer never overlaps compute and
the slowest member stalls the whole ring.  The asynchronous multi-process
path (``core/ring_async.py``, ``cges(engine="async")``,
``launch/ring_async_run.py``) relaxes exactly the barrier column of the
mapping while keeping each member's compute identical:

  * k ring processes        ->  k OS processes (or threads), each running
                                the SAME ges_jit restricted sweep
  * "send BN to successor"  ->  a length-prefixed socket frame posted the
                                moment the sweep finishes; a round-keyed
                                double-buffered mailbox lets the transfer
                                overlap the successor's (W, n) sweep
  * BN fusion               ->  the same unified core/fusion layer, on the
                                receiving member, off the mailbox
  * convergence check       ->  a token circulating the ring (one lap
                                collects every member's round score; the
                                verdict lap commits or stops), with a
                                bounded speculation window instead of pmax
  * membership              ->  ELASTIC: heartbeat failure detection, the
                                dead member's E_i folded into its ring
                                predecessor (partition.remerge_failed
                                semantics), ring re-stitched so k-1
                                members finish the run

Healthy async runs replay the lockstep trajectory exactly (speculative
rounds never diverge because fuse/GES inputs don't depend on verdicts);
the oracle here is what the async tests pin against.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import partition, score_cache
from .ges import GESConfig, ges_jit_body
# One shard_map compat shim for the whole codebase (jax 0.4 check_rep ->
# 0.6 check_vma rename) lives in core/sweeps; the underscore alias keeps
# pre-unification importers of this module working.
from .sweeps import pad_data_rows, shard_map_compat

_shard_map_compat = shard_map_compat
# Fusion lives in ONE place (core/fusion.py); the compat names below are
# re-exported because pre-unification callers imported them from here.
from .fusion import (fuse_trace, fuse_jit, gho_order_jit,  # noqa: F401
                     sigma_consistent_jit)

Array = jax.Array
BIG = jnp.float32(3.0e38)


# ---------------------------------------------------------------------------
# The ring program
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RingSpec:
    k: int                       # ring size (devices along the ring axis)
    axis: str = "ring"           # mesh axis (or tuple) carrying the ring
    max_rounds: int = 16
    axis_model: Optional[str] = None   # optional scoring-TP axis inside each
    axis_model_size: int = 1           # ring process (production mesh: 'model')
    data_axis: Optional[str] = None    # optional instance-axis mesh dim: each
    data_axis_size: int = 1            # device scores its m/d rows + one psum


def _ring_body(data, arities, edge_mask, init_g, pid_table=None,
               *, spec: RingSpec, config: GESConfig, r_max: int,
               add_limit: int):
    """Per-device body under shard_map.  edge_mask/init_g: (1, n, n) local;
    pid_table: optional (1, n, W) local — this process's static E_i candidate
    table, making every sweep of every round W-wide (see ges_jit_body).

    When ``spec.data_axis`` is set, ``data`` arrives as the local (m/d, n)
    row shard and every count build inside ges_jit_body psums over that
    axis (see core/sweeps).  When ``config.family_cache`` is set, a
    per-ring-process family-score cache is threaded through the rounds
    while_loop, so a family scored in round t (or inherited from a
    predecessor's graph) is never recontracted in round t' > t; the body
    then also returns the final (hits, misses) counters.
    """
    axis = spec.axis
    k = spec.k
    n = init_g.shape[1]
    edge_mask = edge_mask[0]
    g0 = init_g[0]
    pids = None if pid_table is None else pid_table[0]

    perm = [(i, (i + 1) % k) for i in range(k)]  # send to successor
    use_cache = bool(config.family_cache)

    def one_round(g_own, cache):
        g_pred = jax.lax.ppermute(g_own, axis, perm)
        fused = fuse_trace(g_own, g_pred)
        out = ges_jit_body(
            data, arities, fused, edge_mask,
            jnp.int32(add_limit),
            config.ess, config.max_parents, config.max_q, r_max,
            config.counts_impl, config.tol, config.incremental,
            config.child_chunk,
            axis_model=spec.axis_model,
            axis_model_size=spec.axis_model_size,
            pid_table=pids,
            data_axis_name=spec.data_axis,
            cache=cache)
        if use_cache:
            adj, score, _, _, cache = out
        else:
            adj, score = out[0], out[1]
        return adj, score, cache

    def cond(state):
        go, rnd = state[4], state[5]
        return go & (rnd < spec.max_rounds)

    def body(state):
        g, g_best, s_best, best, go, rnd = state[:6]
        cache = state[6] if use_cache else None
        adj, score, cache = one_round(g, cache)
        round_best = jax.lax.pmax(score, axis)
        improved = round_best > best + config.tol
        # Keep the graphs of the last GLOBALLY-improving round (Algorithm 1
        # holds onto the best BN): the final non-improving round's graphs
        # are discarded, exactly like the host driver's best_adj, so both
        # engines hand the same winner to the fine-tune pass.
        g_keep = jnp.where(improved, adj, g_best)
        s_keep = jnp.where(improved, score, s_best)
        out = (adj, g_keep, s_keep, jnp.maximum(best, round_best),
               improved, rnd + 1)
        return out + (cache,) if use_cache else out

    state0 = (g0, g0, -BIG, -BIG, jnp.bool_(True), jnp.int32(0))
    if use_cache:
        width = n if pids is None else pids.shape[1]
        state0 = state0 + (score_cache.init(n, width, config.cache_capacity),)
    out = jax.lax.while_loop(cond, body, state0)
    g_best, s_best, rounds = out[1], out[2], out[5]
    if use_cache:
        cache = out[6]
        hm = jnp.stack([cache.hits, cache.misses])[None]   # (1, 2) per device
        return g_best[None], s_best[None], rounds, hm
    return g_best[None], s_best[None], rounds


def build_ring_program(mesh: Mesh, spec: RingSpec, config: GESConfig,
                       r_max: int, add_limit: int, restricted: bool = False):
    """Compile-ready cGES stage-2 program for an arbitrary mesh.

    The ring axis is ``spec.axis``; data/arities are replicated, edge masks
    and graph state are sharded one-per-ring-slot.  Returns a function
    (data, arities, edge_masks, init_graphs) -> (graphs, scores, rounds);
    with ``restricted=True`` the program takes a fifth (k, n, W) int32
    ``pid_tables`` input (partition.pid_tables — one shared static W) and
    every ring process sweeps W-wide instead of full-n-then-mask.

    With ``spec.data_axis`` set (a SECOND mesh axis, orthogonal to the
    ring), the data rows are sharded ``P(data_axis, None)`` so each of the
    k * d devices contracts m/d instances and psums the count tables; the
    caller owns sentinel-padding ragged m (sweeps.pad_data_rows — ring_cges
    does it).  With ``config.family_cache`` the program returns a fourth
    (k, 2) int32 output: per-ring-process (hits, misses) cache counters.
    """
    axis = spec.axis

    body = partial(_ring_body, spec=spec, config=config, r_max=r_max,
                   add_limit=add_limit)

    data_spec = P() if spec.data_axis is None else P(spec.data_axis, None)
    pid_specs = (P(axis, None, None),) if restricted else ()
    stat_specs = (P(axis, None),) if config.family_cache else ()
    mapped = _shard_map_compat(
        body, mesh=mesh,
        in_specs=(data_spec, P(), P(axis, None, None), P(axis, None, None))
        + pid_specs,
        out_specs=(P(axis, None, None), P(axis), P()) + stat_specs,
    )
    return jax.jit(mapped)


def ring_cges(
    data: np.ndarray,
    arities: np.ndarray,
    edge_masks: np.ndarray,
    mesh: Mesh,
    spec: RingSpec,
    config: Optional[GESConfig] = None,
    add_limit: Optional[int] = None,
    restricted: bool = True,
    pid_tables: Optional[np.ndarray] = None,
    return_cache_stats: bool = False,
):
    """Execute the compiled ring on a real mesh (k devices).

    Returns the per-process (graphs, scores) of the last *globally
    improving* round — the best BNs Algorithm 1 keeps, identical to the
    host driver's ``best_adj`` selection — plus the executed round count
    (which includes the final non-improving round).

    ``restricted=True`` (default) derives per-process (n, W) pid tables from
    the edge masks (or takes them via ``pid_tables``) so each compiled
    process pays W = |E_i|-wide sweeps; ``restricted=False`` runs the old
    full-n-masked program (same trajectories, n-wide per-round cost).

    ``spec.data_axis`` shards the instance axis across a second mesh dim
    (rows are sentinel-padded here when m % d != 0 — exact, see
    sweeps.pad_data_rows).  ``return_cache_stats=True`` (requires
    ``config.family_cache``) appends a list of per-process stats dicts
    (hits / misses / hit_rate) to the return tuple.
    """
    k, n, _ = edge_masks.shape
    if k != spec.k:
        # asserts vanish under ``python -O`` and the mismatch would
        # otherwise surface as an opaque shard_map shape error
        raise ValueError(
            f"edge_masks carries k={k} ring members but RingSpec.k="
            f"{spec.k} — the partition and the mesh spec must agree")
    config = config if config is not None else GESConfig()
    r_max = int(arities.max())
    lim = int(n * n if add_limit is None else add_limit)
    prog = build_ring_program(mesh, spec, config, r_max, lim,
                              restricted=restricted)
    data = np.asarray(data)
    if spec.data_axis is not None and spec.data_axis_size > 1:
        data = np.asarray(pad_data_rows(data.astype(np.int32), r_max,
                                        spec.data_axis_size))
    graphs0 = jnp.zeros((k, n, n), dtype=jnp.int8)
    args = [
        jnp.asarray(data.astype(np.int32)),
        jnp.asarray(arities.astype(np.int32)),
        jnp.asarray(edge_masks.astype(np.int8)),
        graphs0,
    ]
    if restricted:
        if pid_tables is None:
            pid_tables = partition.pid_tables(edge_masks)
        args.append(jnp.asarray(np.asarray(pid_tables, dtype=np.int32)))
    out = prog(*args)
    graphs, scores, rounds = out[0], out[1], out[2]
    if return_cache_stats:
        if not config.family_cache:
            raise ValueError("return_cache_stats requires config.family_cache")
        hm = np.asarray(out[3])
        stats = [{"hits": int(h), "misses": int(ms),
                  "hit_rate": float(h) / max(int(h) + int(ms), 1)}
                 for h, ms in hm]
        return np.asarray(graphs), np.asarray(scores), int(rounds), stats
    return np.asarray(graphs), np.asarray(scores), int(rounds)
