"""The ring executor — cGES's learning stage as ONE compiled multi-device
program (shard_map over a "ring" mesh axis).

Mapping of the paper's distributed system onto JAX:

  * k ring processes        ->  k devices (or device groups) on a mesh axis
  * "send BN to successor"  ->  jax.lax.ppermute of the (n, n) int8 adjacency
  * BN fusion               ->  core/fusion.fuse_trace: the traceable engine
                                of the UNIFIED fusion layer (GHO ordering +
                                covered-edge-reversal sink conversion, one
                                maintained longest-path depth vector,
                                vmap-batched sigma transforms) — the same
                                code the host driver dispatches to, not a
                                hand-mirrored copy; this module keeps no
                                fusion math of its own (only re-exports)
  * constrained GES         ->  ges.ges_jit_body (lax.while_loop program);
                                every candidate rescoring inside it — FES
                                insert and BES delete columns alike — goes
                                through the unified core/sweeps engine, so a
                                fused counts_impl fuses BOTH phases of every
                                ring process (insert: one contraction per
                                column; delete: one family-table build per
                                column, marginalized per parent slot)
  * restricted E_i sweeps   ->  a static per-process (n, W) pid_table
                                (partition.pid_tables) rides the ring axis
                                next to the edge masks; ges_jit_body then
                                runs its whole while_loop in (W, n) index
                                space, so each compiled process pays
                                W = |E_i|-wide sweeps per round — the
                                paper's cost argument, end-to-end compiled
                                (restricted=False keeps the old
                                full-n-sweep-then-mask program)
  * convergence check       ->  lax.pmax over per-device best scores

The entire learning stage — all rounds, all k processes — is a single
jit-compiled program; one host call runs cGES's stage 2 to convergence.
This is also the program that is `.lower().compile()`d on the production
(16, 16) and (2, 16, 16) meshes by launch/dryrun.py (arch id: ``cges_ring``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6: public top-level export
    from jax import shard_map as _shard_map
except ImportError:  # pinned jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map


def _shard_map_compat(f, *, mesh, in_specs, out_specs):
    """shard_map across the jax 0.4 -> 0.6 API rename.

    The replication-checker kwarg was renamed ``check_rep`` -> ``check_vma``;
    we disable it either way (the ring body mixes per-device graph state with
    replicated data, which the checker mis-flags on older jax).
    """
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)

from . import partition
from .ges import GESConfig, ges_jit_body
# Fusion lives in ONE place (core/fusion.py); the compat names below are
# re-exported because pre-unification callers imported them from here.
from .fusion import (fuse_trace, fuse_jit, gho_order_jit,  # noqa: F401
                     sigma_consistent_jit)

Array = jax.Array
BIG = jnp.float32(3.0e38)


# ---------------------------------------------------------------------------
# The ring program
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RingSpec:
    k: int                       # ring size (devices along the ring axis)
    axis: str = "ring"           # mesh axis (or tuple) carrying the ring
    max_rounds: int = 16
    axis_model: Optional[str] = None   # optional scoring-TP axis inside each
    axis_model_size: int = 1           # ring process (production mesh: 'model')


def _ring_body(data, arities, edge_mask, init_g, pid_table=None,
               *, spec: RingSpec, config: GESConfig, r_max: int,
               add_limit: int):
    """Per-device body under shard_map.  edge_mask/init_g: (1, n, n) local;
    pid_table: optional (1, n, W) local — this process's static E_i candidate
    table, making every sweep of every round W-wide (see ges_jit_body)."""
    axis = spec.axis
    k = spec.k
    n = data.shape[1]
    edge_mask = edge_mask[0]
    g0 = init_g[0]
    pids = None if pid_table is None else pid_table[0]

    perm = [(i, (i + 1) % k) for i in range(k)]  # send to successor

    def one_round(g_own):
        g_pred = jax.lax.ppermute(g_own, axis, perm)
        fused = fuse_trace(g_own, g_pred)
        adj, score, n_ins, n_del = ges_jit_body(
            data, arities, fused, edge_mask,
            jnp.int32(add_limit),
            config.ess, config.max_parents, config.max_q, r_max,
            config.counts_impl, config.tol, config.incremental,
            config.child_chunk,
            axis_model=spec.axis_model,
            axis_model_size=spec.axis_model_size,
            pid_table=pids)
        return adj, score

    def cond(state):
        g, g_best, s_best, best, go, rnd = state
        return go & (rnd < spec.max_rounds)

    def body(state):
        g, g_best, s_best, best, go, rnd = state
        adj, score = one_round(g)
        round_best = jax.lax.pmax(score, axis)
        improved = round_best > best + config.tol
        # Keep the graphs of the last GLOBALLY-improving round (Algorithm 1
        # holds onto the best BN): the final non-improving round's graphs
        # are discarded, exactly like the host driver's best_adj, so both
        # engines hand the same winner to the fine-tune pass.
        g_keep = jnp.where(improved, adj, g_best)
        s_keep = jnp.where(improved, score, s_best)
        return (adj, g_keep, s_keep, jnp.maximum(best, round_best),
                improved, rnd + 1)

    state0 = (g0, g0, -BIG, -BIG, jnp.bool_(True), jnp.int32(0))
    _, g_best, s_best, _, _, rounds = jax.lax.while_loop(cond, body, state0)
    return g_best[None], s_best[None], rounds


def build_ring_program(mesh: Mesh, spec: RingSpec, config: GESConfig,
                       r_max: int, add_limit: int, restricted: bool = False):
    """Compile-ready cGES stage-2 program for an arbitrary mesh.

    The ring axis is ``spec.axis``; data/arities are replicated, edge masks
    and graph state are sharded one-per-ring-slot.  Returns a function
    (data, arities, edge_masks, init_graphs) -> (graphs, scores, rounds);
    with ``restricted=True`` the program takes a fifth (k, n, W) int32
    ``pid_tables`` input (partition.pid_tables — one shared static W) and
    every ring process sweeps W-wide instead of full-n-then-mask.
    """
    axis = spec.axis

    body = partial(_ring_body, spec=spec, config=config, r_max=r_max,
                   add_limit=add_limit)

    pid_specs = (P(axis, None, None),) if restricted else ()
    mapped = _shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(), P(), P(axis, None, None), P(axis, None, None))
        + pid_specs,
        out_specs=(P(axis, None, None), P(axis), P()),
    )
    return jax.jit(mapped)


def ring_cges(
    data: np.ndarray,
    arities: np.ndarray,
    edge_masks: np.ndarray,
    mesh: Mesh,
    spec: RingSpec,
    config: Optional[GESConfig] = None,
    add_limit: Optional[int] = None,
    restricted: bool = True,
    pid_tables: Optional[np.ndarray] = None,
):
    """Execute the compiled ring on a real mesh (k devices).

    Returns the per-process (graphs, scores) of the last *globally
    improving* round — the best BNs Algorithm 1 keeps, identical to the
    host driver's ``best_adj`` selection — plus the executed round count
    (which includes the final non-improving round).

    ``restricted=True`` (default) derives per-process (n, W) pid tables from
    the edge masks (or takes them via ``pid_tables``) so each compiled
    process pays W = |E_i|-wide sweeps; ``restricted=False`` runs the old
    full-n-masked program (same trajectories, n-wide per-round cost).
    """
    k, n, _ = edge_masks.shape
    assert k == spec.k
    config = config if config is not None else GESConfig()
    r_max = int(arities.max())
    lim = int(n * n if add_limit is None else add_limit)
    prog = build_ring_program(mesh, spec, config, r_max, lim,
                              restricted=restricted)
    graphs0 = jnp.zeros((k, n, n), dtype=jnp.int8)
    args = [
        jnp.asarray(data.astype(np.int32)),
        jnp.asarray(arities.astype(np.int32)),
        jnp.asarray(edge_masks.astype(np.int8)),
        graphs0,
    ]
    if restricted:
        if pid_tables is None:
            pid_tables = partition.pid_tables(edge_masks)
        args.append(jnp.asarray(np.asarray(pid_tables, dtype=np.int32)))
    graphs, scores, rounds = prog(*args)
    return np.asarray(graphs), np.asarray(scores), int(rounds)
