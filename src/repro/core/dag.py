"""DAG utilities for Bayesian-network structure learning.

Graphs are dense adjacency matrices ``A`` of shape (n, n) with
``A[x, y] == 1``  meaning a directed edge  ``x -> y``  (x is a parent of y).
Two mirrored engines are provided:

* numpy (host) versions for the orchestration / fusion path, and
* jnp (device) versions that are jit-safe (fixed shapes, no data-dependent
  Python control flow) for use inside the ring executor's compiled sweeps.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Reachability / acyclicity
# ---------------------------------------------------------------------------

def transitive_closure_np(adj: np.ndarray) -> np.ndarray:
    """Boolean reachability matrix R, R[a, b] = 1 iff a path a -> ... -> b exists.

    Repeated boolean squaring: O(n^3 log n) bitset-backed via numpy matmul.
    """
    n = adj.shape[0]
    reach = adj.astype(bool)
    steps = max(1, int(np.ceil(np.log2(max(n, 2)))))
    for _ in range(steps):
        nxt = reach | (reach @ reach)
        if np.array_equal(nxt, reach):
            break
        reach = nxt
    return reach


def transitive_closure(adj: Array) -> Array:
    """jnp mirror of :func:`transitive_closure_np` (fixed trip count, jittable)."""
    n = adj.shape[0]
    steps = max(1, int(np.ceil(np.log2(max(n, 2)))))
    reach = adj.astype(bool)

    def body(_, r):
        return r | (r.astype(jnp.float32) @ r.astype(jnp.float32) > 0)

    return jax.lax.fori_loop(0, steps, body, reach)


def is_dag_np(adj: np.ndarray) -> bool:
    reach = transitive_closure_np(adj)
    return not bool(np.any(np.diag(reach)))


def is_dag(adj: Array) -> Array:
    reach = transitive_closure(adj)
    return ~jnp.any(jnp.diagonal(reach))


def closure_after_edge(reach: Array, x, y) -> Array:
    """Incremental closure update after inserting edge x -> y.

    Anything that reaches x (or is x) now reaches anything y reaches (or y).
    Rank-1 boolean update, O(n^2); works for numpy and jnp inputs.
    """
    n = reach.shape[0]
    if isinstance(reach, np.ndarray):
        src = reach[:, x].copy()
        src[x] = True
        dst = reach[y, :].copy()
        dst[y] = True
        return reach | np.outer(src, dst)
    src = reach[:, x].at[x].set(True)
    dst = reach[y, :].at[y].set(True)
    return reach | jnp.outer(src, dst)


def topological_order_np(adj: np.ndarray) -> np.ndarray:
    """Kahn's algorithm. Raises ValueError on cyclic input."""
    n = adj.shape[0]
    adj = adj.astype(bool).copy()
    indeg = adj.sum(axis=0)
    order = []
    ready = sorted(np.flatnonzero(indeg == 0).tolist())
    while ready:
        v = ready.pop(0)
        order.append(v)
        for w in np.flatnonzero(adj[v]):
            adj[v, w] = False
            indeg[w] -= 1
            if indeg[w] == 0:
                ready.append(int(w))
        ready.sort()
    if len(order) != n:
        raise ValueError("graph has a cycle")
    return np.asarray(order, dtype=np.int64)


# ---------------------------------------------------------------------------
# Moral graph / metrics support
# ---------------------------------------------------------------------------

def moral_graph_np(adj: np.ndarray) -> np.ndarray:
    """Undirected moralized graph: skeleton + marry all co-parents."""
    adj = adj.astype(bool)
    und = adj | adj.T
    # marry parents:  P^T P  has [i,j] > 0 iff i and j share a child.
    co_parent = (adj.astype(np.int64) @ adj.astype(np.int64).T) > 0
    moral = und | co_parent
    np.fill_diagonal(moral, False)
    return moral


def smhd_np(adj_a: np.ndarray, adj_b: np.ndarray) -> int:
    """Structural Moral Hamming Distance: edge mismatches between moral graphs."""
    ma, mb = moral_graph_np(adj_a), moral_graph_np(adj_b)
    diff = np.triu(ma ^ mb, k=1)
    return int(diff.sum())


def shd_np(adj_a: np.ndarray, adj_b: np.ndarray) -> int:
    """Plain structural Hamming distance on directed adjacencies."""
    return int(np.sum(adj_a.astype(bool) != adj_b.astype(bool)))


# ---------------------------------------------------------------------------
# DAG -> CPDAG (Chickering 1995 order-edges + compelled labelling)
# ---------------------------------------------------------------------------

def dag_to_cpdag_np(adj: np.ndarray) -> np.ndarray:
    """Return CPDAG mixed graph: C[x,y]=C[y,x]=1 for reversible edges,
    C[x,y]=1, C[y,x]=0 for compelled x->y.
    """
    adj = adj.astype(bool)
    n = adj.shape[0]
    topo = topological_order_np(adj)
    pos = np.empty(n, dtype=np.int64)
    pos[topo] = np.arange(n)

    # Order edges: (y ascending by topo of child, x descending by topo of parent)
    edges = [(int(x), int(y)) for x in range(n) for y in range(n) if adj[x, y]]
    edges.sort(key=lambda e: (pos[e[1]], -pos[e[0]]))

    UNKNOWN, COMPELLED, REVERSIBLE = 0, 1, 2
    label = {e: UNKNOWN for e in edges}

    for (x, y) in edges:
        if label[(x, y)] != UNKNOWN:
            continue
        done = False
        # step: for every w -> x compelled
        for w in np.flatnonzero(adj[:, x]):
            w = int(w)
            if label.get((w, x)) == COMPELLED:
                if not adj[w, y]:
                    # label x->y and every edge into y compelled
                    for p in np.flatnonzero(adj[:, y]):
                        label[(int(p), y)] = COMPELLED
                    done = True
                    break
                else:
                    label[(w, y)] = COMPELLED
        if done:
            continue
        # if there exists z -> y with z != x and z not a parent of x => compelled
        parents_y = set(int(p) for p in np.flatnonzero(adj[:, y]))
        exists_z = any((z != x) and (not adj[z, x]) for z in parents_y)
        if exists_z:
            for p in parents_y:
                if label[(p, y)] == UNKNOWN:
                    label[(p, y)] = COMPELLED
        else:
            for p in parents_y:
                if label[(p, y)] == UNKNOWN:
                    label[(p, y)] = REVERSIBLE

    cpdag = np.zeros_like(adj, dtype=bool)
    for (x, y), lab in label.items():
        cpdag[x, y] = True
        if lab == REVERSIBLE:
            cpdag[y, x] = True
    return cpdag


def random_dag_np(
    rng: np.random.Generator, n: int, n_edges: int, max_parents: int = 6
) -> np.ndarray:
    """Random DAG with ~n_edges edges under a random topological order."""
    order = rng.permutation(n)
    adj = np.zeros((n, n), dtype=bool)
    pairs = [(i, j) for j in range(1, n) for i in range(j)]
    rng.shuffle(pairs)
    added = 0
    indeg = np.zeros(n, dtype=np.int64)
    for i, j in pairs:
        if added >= n_edges:
            break
        x, y = int(order[i]), int(order[j])
        if indeg[y] >= max_parents:
            continue
        adj[x, y] = True
        indeg[y] += 1
        added += 1
    return adj
