"""Persistent device-resident family-score cache for GES sweeps.

Scutari et al. (arXiv:1804.08137) observe that greedy-search wall time is
dominated by *redundant* family (child, parent-set) score evaluations, and
the cGES ring makes the redundancy extreme: the same family recurs across
GES iterations (most columns are untouched by an edge application), across
ring rounds (graphs converge), and across ring members (edge subsets trade
ownership of the same children).  This module memoises the unit both score
engines actually produce — the masked candidate-score COLUMN of one
(child, parent-set) family under one candidate set (a batch of family
scores: entry x is the family score of Pa_y +/- {x} minus the base, masked
to the legal toggles) — in a fixed-capacity, set-associative table that
lives on device and is threaded through ``lax.while_loop`` carries, so a
hit skips the whole O(m)-contraction sweep via ``lax.cond``.

Key contract (exactness): a column is fully determined by
``(kind, child, parents-of-child, scope)`` where ``scope`` identifies the
candidate set / restriction program (ring members hash their allowed-edge
column into it; full-n programs use 0).  Keys are stored EXACTLY —
``2 + ceil(n/32)`` packed int32 words (kind/child word, scope word, parent
bitmask) — and compared word-for-word, so there are no hash collisions to
corrupt a trajectory: the set-index hash only picks WHERE a key lives, never
WHETHER it matches.  Cached-vs-uncached trajectories are therefore
bitwise-identical as long as the compute closure is deterministic.

Eviction (in the spirit of prioritized experience replay): each slot carries
``prio = access_step + GAIN_WEIGHT * sigmoid(max(column))`` — a recency
ramp plus a bounded bonus for columns that still contain a positive score
delta (families whose neighborhood can still improve the graph are the ones
greedy search revisits).  The victim is the min-priority way of the key's
set; empty slots sit at -inf priority so they fill first.

Data-axis interplay: when sweeps shard the instance axis, every device on
the data axis carries an identical replica of the cache state (the psum'd
columns are identical, so the states evolve in lockstep) — hence the
``lax.cond`` hit/miss predicate is replicated too and the psum inside the
miss branch cannot deadlock.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

WAYS = 4                 # set associativity
GAIN_WEIGHT = 8.0        # max priority bonus, in units of access steps
KIND_INSERT = 0
KIND_DELETE = 1

_FNV_OFFSET = jnp.uint32(2166136261)
_FNV_PRIME = jnp.uint32(16777619)


class FamilyScoreCache(NamedTuple):
    """Device-resident cache state (a pytree — carries through while_loop).

    keys: (C, KW) int32 — packed exact keys; word 0 == -1 marks empty.
    vals: (C, V)  f32   — cached masked score columns (V = W or n, static).
    prio: (C,)    f32   — eviction priority (-inf = empty).
    step/hits/misses: () int32 — access counter + statistics.
    """
    keys: Array
    vals: Array
    prio: Array
    step: Array
    hits: Array
    misses: Array


def key_words(n_vars: int) -> int:
    return 2 + (n_vars + 31) // 32


def init(n_vars: int, width: int, capacity: int = 1024) -> FamilyScoreCache:
    """Fresh cache for (n_vars)-variable problems with (width,) columns.

    ``capacity`` is rounded up to a multiple of WAYS.
    """
    cap = max(int(capacity), WAYS)
    cap = ((cap + WAYS - 1) // WAYS) * WAYS
    return FamilyScoreCache(
        keys=jnp.full((cap, key_words(n_vars)), -1, dtype=jnp.int32),
        vals=jnp.zeros((cap, width), dtype=jnp.float32),
        prio=jnp.full((cap,), -jnp.inf, dtype=jnp.float32),
        step=jnp.int32(0),
        hits=jnp.int32(0),
        misses=jnp.int32(0),
    )


def _pack_key(kind_code, child, parent_mask: Array, scope) -> Array:
    """Exact (KW,) int32 key: [child*4 + kind, scope, mask words...]."""
    n = parent_mask.shape[0]
    kw = (n + 31) // 32
    bits = jnp.zeros((kw * 32,), jnp.uint32).at[:n].set(
        parent_mask.astype(jnp.uint32))
    words = (bits.reshape(kw, 32)
             << jnp.arange(32, dtype=jnp.uint32)[None, :]).sum(
        axis=1, dtype=jnp.uint32)
    word0 = (jnp.asarray(child, jnp.int32) * 4
             + jnp.asarray(kind_code, jnp.int32))
    return jnp.concatenate([
        word0[None],
        jnp.asarray(scope, jnp.int32)[None],
        jax.lax.bitcast_convert_type(words, jnp.int32),
    ])


def _set_slots(cache: FamilyScoreCache, key: Array) -> Array:
    """(WAYS,) slot indices of the key's set (FNV-1a over the key words —
    the hash only PLACES entries; matching is exact, word-for-word)."""
    n_sets = cache.keys.shape[0] // WAYS
    h = _FNV_OFFSET
    for i in range(cache.keys.shape[1]):
        w = jax.lax.bitcast_convert_type(key[i], jnp.uint32)
        h = (h ^ w) * _FNV_PRIME
    s = (h % jnp.uint32(n_sets)).astype(jnp.int32)
    return s * WAYS + jnp.arange(WAYS, dtype=jnp.int32)


def _priority(step: Array, col: Array) -> Array:
    """Recency ramp + bounded score-gain bonus (PER-flavoured)."""
    gain = jnp.max(col)          # -inf when no legal toggle improves: bonus 0
    return step.astype(jnp.float32) + GAIN_WEIGHT * jax.nn.sigmoid(gain)


def lookup_or_compute(
    cache: FamilyScoreCache,
    kind_code,
    child,
    parent_mask: Array,
    scope,
    compute_fn: Callable[[], Array],
) -> Tuple[Array, FamilyScoreCache]:
    """Return the (V,) column for this family, computing it only on miss.

    Traceable (gather/scatter + one ``lax.cond``), so it lives inside
    ``lax.while_loop``/``lax.scan`` bodies; on a hit the whole compute
    closure — the O(m) count contraction — is skipped.
    """
    key = _pack_key(kind_code, child, parent_mask, scope)
    slots = _set_slots(cache, key)
    match = jnp.all(cache.keys[slots] == key[None, :], axis=1)
    hit = jnp.any(match)
    step = cache.step + jnp.int32(1)

    def on_hit(c: FamilyScoreCache):
        slot = slots[jnp.argmax(match)]
        col = c.vals[slot]
        return col, c._replace(
            prio=c.prio.at[slot].set(_priority(step, col)),
            step=step,
            hits=c.hits + jnp.int32(1))

    def on_miss(c: FamilyScoreCache):
        col = compute_fn()
        victim = slots[jnp.argmin(c.prio[slots])]
        return col, c._replace(
            keys=c.keys.at[victim].set(key),
            vals=c.vals.at[victim].set(col),
            prio=c.prio.at[victim].set(_priority(step, col)),
            step=step,
            misses=c.misses + jnp.int32(1))

    return jax.lax.cond(hit, on_hit, on_miss, cache)


def probe(
    cache: FamilyScoreCache, kind_code, child, parent_mask: Array, scope
) -> Tuple[Array, Array, FamilyScoreCache]:
    """Hit test for HOST drivers: (hit, col, cache').

    The host driver cannot close its (python) sweep over a traced branch, so
    the lookup splits in two: ``probe`` (jit-able) answers hit/miss and
    refreshes recency on hit; on miss the host runs its own sweep and calls
    :func:`insert`.  ``col`` is garbage when ``hit`` is False.
    """
    key = _pack_key(kind_code, child, parent_mask, scope)
    slots = _set_slots(cache, key)
    match = jnp.all(cache.keys[slots] == key[None, :], axis=1)
    hit = jnp.any(match)
    slot = slots[jnp.argmax(match)]
    col = cache.vals[slot]
    step = cache.step + jnp.int32(1)
    cache = cache._replace(
        prio=cache.prio.at[slot].set(
            jnp.where(hit, _priority(step, col), cache.prio[slot])),
        step=jnp.where(hit, step, cache.step),
        hits=cache.hits + hit.astype(jnp.int32))
    return hit, col, cache


def insert(
    cache: FamilyScoreCache, kind_code, child, parent_mask: Array, scope,
    col: Array,
) -> FamilyScoreCache:
    """Store a host-computed column after a :func:`probe` miss."""
    key = _pack_key(kind_code, child, parent_mask, scope)
    slots = _set_slots(cache, key)
    step = cache.step + jnp.int32(1)
    victim = slots[jnp.argmin(cache.prio[slots])]
    return cache._replace(
        keys=cache.keys.at[victim].set(key),
        vals=cache.vals.at[victim].set(col),
        prio=cache.prio.at[victim].set(_priority(step, col)),
        step=step,
        misses=cache.misses + jnp.int32(1))


def stats(cache: FamilyScoreCache) -> dict:
    """Host-side statistics: hits, misses, hit rate, occupancy."""
    hits = int(cache.hits)
    misses = int(cache.misses)
    total = hits + misses
    occupied = int((cache.keys[:, 0] >= 0).sum())
    return {
        "hits": hits,
        "misses": misses,
        "hit_rate": (hits / total) if total else 0.0,
        "capacity": int(cache.keys.shape[0]),
        "occupied": occupied,
    }


_probe_jit = jax.jit(probe)
_insert_jit = jax.jit(insert)
