"""Edge partitioning (paper §3 stage 1).

Score-guided agglomerative clustering of *variables* using the BDeu-delta
similarity s(X_i, X_j) (Eq. 4), merged with the average-pairwise linkage of
Eq. 5 (the paper labels it complete-link but writes the average formula — we
implement the formula).  The k variable clusters induce k disjoint edge
subsets: within-cluster edges go to their cluster; cross-cluster edges are
assigned to the currently smallest subset (load balancing, as in the paper).
"""
from __future__ import annotations

from typing import List

import numpy as np
import jax.numpy as jnp

from . import bdeu


def variable_clusters(similarity: np.ndarray, k: int) -> List[List[int]]:
    """Agglomerative clustering with Eq.-5 average linkage down to k clusters."""
    n = similarity.shape[0]
    if k >= n:
        return [[i] for i in range(n)]
    clusters: List[List[int]] = [[i] for i in range(n)]
    # Pairwise *sum* of similarities between clusters; Eq. 5 divides by
    # |Cr||Cl| when comparing.
    sims = similarity.astype(np.float64).copy()
    np.fill_diagonal(sims, 0.0)
    sum_s = sims.copy()                     # sum_s[a, b] = sum of pair sims
    sizes = np.ones(n, dtype=np.int64)
    alive = np.ones(n, dtype=bool)

    while alive.sum() > k:
        denom = np.outer(sizes, sizes).astype(np.float64)
        with np.errstate(invalid="ignore"):
            link = sum_s / denom
        link[~alive, :] = -np.inf
        link[:, ~alive] = -np.inf
        np.fill_diagonal(link, -np.inf)
        a, b = np.unravel_index(np.argmax(link), link.shape)
        if a > b:
            a, b = b, a
        # merge b into a
        clusters[a] = clusters[a] + clusters[b]
        clusters[b] = []
        sum_s[a, :] += sum_s[b, :]
        sum_s[:, a] += sum_s[:, b]
        sum_s[a, a] = 0.0
        sizes[a] += sizes[b]
        alive[b] = False
        sum_s[b, :] = 0.0
        sum_s[:, b] = 0.0

    return [c for c in clusters if c]


def edge_subsets(clusters: List[List[int]], n: int) -> np.ndarray:
    """Return (k, n, n) boolean masks E_1..E_k — disjoint, covering all
    off-diagonal ordered pairs.

    Within-cluster edges -> that cluster's subset.  Cross-cluster edges are
    assigned (both directions together, X->Y and Y->X) to the subset that is
    currently smallest, per the paper's balancing rule.

    The greedy smallest-subset assignment is fully vectorized: walking the
    cross pairs in deterministic (x asc, y asc) order and giving each to the
    currently-smallest subset (+2 edges, ties -> lowest index) is exactly the
    k-way merge of k sorted streams — subset i's c-th grab happens at size
    ``sizes[i] + 2c`` — so sorting all (size, index) tokens lexicographically
    and keeping the first P reproduces the sequential loop's target sequence
    token-for-token (mask-identity regression-tested).  The old O(n^2)
    Python loop was ~500k iterations at the paper's n = 1000 and dominated
    stage 1.
    """
    k = len(clusters)
    masks = np.zeros((k, n, n), dtype=bool)
    cluster_of = np.empty(n, dtype=np.int64)
    for ci, members in enumerate(clusters):
        idx = np.asarray(members, dtype=np.int64)
        cluster_of[idx] = ci
        if idx.size:
            masks[ci][np.ix_(idx, idx)] = True
            np.fill_diagonal(masks[ci], False)
    sizes = masks.sum(axis=(1, 2))

    # deterministic order over cross pairs: x ascending, then y ascending
    xs, ys = np.triu_indices(n, 1)
    cross = cluster_of[xs] != cluster_of[ys] if n else np.zeros(0, bool)
    xs, ys = xs[cross], ys[cross]
    p = xs.size
    if p:
        c = np.arange(p, dtype=np.int64)
        vals = sizes[:, None].astype(np.int64) + 2 * c[None, :]     # (k, p)
        subset = np.broadcast_to(np.arange(k)[:, None], (k, p))
        order = np.lexsort((subset.ravel(), vals.ravel()))[:p]
        tgt = order // p                       # token row = its subset index
        masks[tgt, xs, ys] = True
        masks[tgt, ys, xs] = True
    return masks


def pid_table_from_allowed(allowed: np.ndarray,
                           width: int | None = None) -> np.ndarray:
    """Static (n, W) candidate-parent table for one allowed-edge mask.

    Row y lists the candidate parents x with ``allowed[x, y]`` (ascending),
    padded to the static width W with ``y`` itself — a self-loop, which every
    sweep masks to -inf, so padding slots can never be selected.  W defaults
    to the max column occupancy of ``allowed`` (at least 1); it may be forced
    wider with ``width`` (the ring pads all k processes to one shared W so
    the shard_map program has a single static shape).

    This is the device-side form of the paper's restricted edge sets E_i:
    a compiled sweep over the table pays W = |E_i| per column, not n.

    Degenerate shapes are well-defined rather than errors: n == 0 yields a
    (0, 0) table (nothing to sweep), n == 1 and all-empty masks yield
    all-self-pad tables (every slot invalid by convention, so sweeps return
    all--inf columns) — the shapes an empty E_i or a trivial partition hands
    the ring.
    """
    allowed = np.asarray(allowed, dtype=bool).copy()
    n = allowed.shape[0]
    if n:
        np.fill_diagonal(allowed, False)
    occ = int(allowed.sum(axis=0).max()) if n else 0
    W = (max(1, occ) if n else 0) if width is None else int(width)
    if W < occ:
        raise ValueError(f"width {W} < max column occupancy {occ}")
    if W > n:
        raise ValueError(f"width {W} exceeds n = {n}")
    table = np.empty((n, W), dtype=np.int32)
    for y in range(n):
        ids = np.flatnonzero(allowed[:, y])
        table[y, :ids.size] = ids
        table[y, ids.size:] = y              # self-pad (invalid by convention)
    return table


def pid_tables(edge_masks: np.ndarray, width: int | None = None) -> np.ndarray:
    """(k, n, W) per-process candidate tables from (k, n, n) edge masks E_i.

    All processes share one static W (the max column occupancy over the whole
    partition, or ``width``) so the tables can ride a shard_map axis.

    Degenerate inputs (n in {0, 1}, all-empty E_i) produce well-defined
    all-self-pad / zero-width tables instead of raising — see
    :func:`pid_table_from_allowed`.
    """
    k, n, _ = edge_masks.shape
    masks = np.asarray(edge_masks, dtype=bool)
    occ = 0
    for i in range(k):
        off = masks[i].copy()
        if n:
            np.fill_diagonal(off, False)
            occ = max(occ, int(off.sum(axis=0).max()))
    W = (max(1, occ) if n else 0) if width is None else int(width)
    return np.stack([pid_table_from_allowed(masks[i], width=W)
                     for i in range(k)]) if k else np.zeros((0, n, W),
                                                            dtype=np.int32)


def remerge_failed(edge_masks: np.ndarray, failed: int) -> np.ndarray:
    """Elastic ring repair: fold a failed member's edge subset into its ring
    predecessor.

    E_1..E_k are a disjoint cover of all candidate edges, so re-merging
    preserves the cover exactly — the ring shrinks from k to k-1 processes
    and the learning stage continues with no loss of search space.  (cGES's
    correctness only needs the union of subsets to equal E; the elastic-ring
    behaviour is exercised by tests/test_fault_tolerance.py.)
    """
    k = edge_masks.shape[0]
    pred = (failed - 1) % k
    out = np.delete(edge_masks, failed, axis=0).copy()
    new_pred = pred if pred < failed else pred - 1
    out[new_pred] |= edge_masks[failed]
    return out


def partition_edges(
    data: np.ndarray,
    arities: np.ndarray,
    k: int,
    ess: float = 10.0,
    engine: str = "fast",
) -> np.ndarray:
    """Full stage-1 pipeline: similarity -> clusters -> (k, n, n) edge masks.

    engine="fast" (default) computes ALL n^2 pairwise tables from one
    contingency matmul (bdeu.pairwise_similarity_fast) — same values as the
    per-pair oracles, ~1000x fewer dispatches (see EXPERIMENTS §Perf it.0).
    """
    n = data.shape[1]
    if engine == "host":
        sims = bdeu.pairwise_similarity_np(data, arities, ess)
    elif engine == "fast":
        sims = bdeu.pairwise_similarity_fast(data, arities, ess)
    elif engine == "jax":
        r_max = int(arities.max())
        sims = np.asarray(
            bdeu.pairwise_similarity_jax(
                jnp.asarray(data.astype(np.int32)),
                jnp.asarray(arities.astype(np.int32)),
                ess, r_max,
            )
        )
    else:
        raise ValueError(
            f"partition_edges: unknown engine {engine!r} "
            f"(valid: 'host', 'fast', 'jax')")
    clusters = variable_clusters(sims, k)
    return edge_subsets(clusters, n)
