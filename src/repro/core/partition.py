"""Edge partitioning (paper §3 stage 1).

Score-guided agglomerative clustering of *variables* using the BDeu-delta
similarity s(X_i, X_j) (Eq. 4), merged with the average-pairwise linkage of
Eq. 5 (the paper labels it complete-link but writes the average formula — we
implement the formula).  The k variable clusters induce k disjoint edge
subsets: within-cluster edges go to their cluster; cross-cluster edges are
assigned to the currently smallest subset (load balancing, as in the paper).
"""
from __future__ import annotations

from typing import List

import numpy as np
import jax.numpy as jnp

from . import bdeu


def variable_clusters(similarity: np.ndarray, k: int) -> List[List[int]]:
    """Agglomerative clustering with Eq.-5 average linkage down to k clusters."""
    n = similarity.shape[0]
    if k >= n:
        return [[i] for i in range(n)]
    clusters: List[List[int]] = [[i] for i in range(n)]
    # Pairwise *sum* of similarities between clusters; Eq. 5 divides by
    # |Cr||Cl| when comparing.
    sims = similarity.astype(np.float64).copy()
    np.fill_diagonal(sims, 0.0)
    sum_s = sims.copy()                     # sum_s[a, b] = sum of pair sims
    sizes = np.ones(n, dtype=np.int64)
    alive = np.ones(n, dtype=bool)

    while alive.sum() > k:
        denom = np.outer(sizes, sizes).astype(np.float64)
        with np.errstate(invalid="ignore"):
            link = sum_s / denom
        link[~alive, :] = -np.inf
        link[:, ~alive] = -np.inf
        np.fill_diagonal(link, -np.inf)
        a, b = np.unravel_index(np.argmax(link), link.shape)
        if a > b:
            a, b = b, a
        # merge b into a
        clusters[a] = clusters[a] + clusters[b]
        clusters[b] = []
        sum_s[a, :] += sum_s[b, :]
        sum_s[:, a] += sum_s[:, b]
        sum_s[a, a] = 0.0
        sizes[a] += sizes[b]
        alive[b] = False
        sum_s[b, :] = 0.0
        sum_s[:, b] = 0.0

    return [c for c in clusters if c]


def edge_subsets(clusters: List[List[int]], n: int) -> np.ndarray:
    """Return (k, n, n) boolean masks E_1..E_k — disjoint, covering all
    off-diagonal ordered pairs.

    Within-cluster edges -> that cluster's subset.  Cross-cluster edges are
    assigned (both directions together, X->Y and Y->X) to the subset that is
    currently smallest, per the paper's balancing rule.
    """
    k = len(clusters)
    masks = np.zeros((k, n, n), dtype=bool)
    cluster_of = np.empty(n, dtype=np.int64)
    for ci, members in enumerate(clusters):
        for v in members:
            cluster_of[v] = ci
        for x in members:
            for y in members:
                if x != y:
                    masks[ci, x, y] = True
    sizes = masks.sum(axis=(1, 2))
    # deterministic order over cross pairs
    for x in range(n):
        for y in range(x + 1, n):
            if cluster_of[x] != cluster_of[y]:
                tgt = int(np.argmin(sizes))
                masks[tgt, x, y] = True
                masks[tgt, y, x] = True
                sizes[tgt] += 2
    return masks


def pid_table_from_allowed(allowed: np.ndarray,
                           width: int | None = None) -> np.ndarray:
    """Static (n, W) candidate-parent table for one allowed-edge mask.

    Row y lists the candidate parents x with ``allowed[x, y]`` (ascending),
    padded to the static width W with ``y`` itself — a self-loop, which every
    sweep masks to -inf, so padding slots can never be selected.  W defaults
    to the max column occupancy of ``allowed`` (at least 1); it may be forced
    wider with ``width`` (the ring pads all k processes to one shared W so
    the shard_map program has a single static shape).

    This is the device-side form of the paper's restricted edge sets E_i:
    a compiled sweep over the table pays W = |E_i| per column, not n.
    """
    allowed = np.asarray(allowed, dtype=bool).copy()
    n = allowed.shape[0]
    np.fill_diagonal(allowed, False)
    occ = int(allowed.sum(axis=0).max()) if n else 0
    W = max(1, occ) if width is None else int(width)
    if W < max(1, occ):
        raise ValueError(f"width {W} < max column occupancy {occ}")
    if W > n:
        raise ValueError(f"width {W} exceeds n = {n}")
    table = np.empty((n, W), dtype=np.int32)
    for y in range(n):
        ids = np.flatnonzero(allowed[:, y])
        table[y, :ids.size] = ids
        table[y, ids.size:] = y              # self-pad (invalid by convention)
    return table


def pid_tables(edge_masks: np.ndarray, width: int | None = None) -> np.ndarray:
    """(k, n, W) per-process candidate tables from (k, n, n) edge masks E_i.

    All processes share one static W (the max column occupancy over the whole
    partition, or ``width``) so the tables can ride a shard_map axis.
    """
    k, n, _ = edge_masks.shape
    masks = np.asarray(edge_masks, dtype=bool)
    occ = 0
    for i in range(k):
        off = masks[i].copy()
        np.fill_diagonal(off, False)
        occ = max(occ, int(off.sum(axis=0).max()))
    W = max(1, occ) if width is None else int(width)
    return np.stack([pid_table_from_allowed(masks[i], width=W)
                     for i in range(k)])


def remerge_failed(edge_masks: np.ndarray, failed: int) -> np.ndarray:
    """Elastic ring repair: fold a failed member's edge subset into its ring
    predecessor.

    E_1..E_k are a disjoint cover of all candidate edges, so re-merging
    preserves the cover exactly — the ring shrinks from k to k-1 processes
    and the learning stage continues with no loss of search space.  (cGES's
    correctness only needs the union of subsets to equal E; see DESIGN.md
    fault-tolerance notes.)
    """
    k = edge_masks.shape[0]
    pred = (failed - 1) % k
    out = np.delete(edge_masks, failed, axis=0).copy()
    new_pred = pred if pred < failed else pred - 1
    out[new_pred] |= edge_masks[failed]
    return out


def partition_edges(
    data: np.ndarray,
    arities: np.ndarray,
    k: int,
    ess: float = 10.0,
    engine: str = "fast",
) -> np.ndarray:
    """Full stage-1 pipeline: similarity -> clusters -> (k, n, n) edge masks.

    engine="fast" (default) computes ALL n^2 pairwise tables from one
    contingency matmul (bdeu.pairwise_similarity_fast) — same values as the
    per-pair oracles, ~1000x fewer dispatches (see EXPERIMENTS §Perf it.0).
    """
    n = data.shape[1]
    if engine == "host":
        sims = bdeu.pairwise_similarity_np(data, arities, ess)
    elif engine == "fast":
        sims = bdeu.pairwise_similarity_fast(data, arities, ess)
    else:
        r_max = int(arities.max())
        sims = np.asarray(
            bdeu.pairwise_similarity_jax(
                jnp.asarray(data.astype(np.int32)),
                jnp.asarray(arities.astype(np.int32)),
                ess, r_max,
            )
        )
    clusters = variable_clusters(sims, k)
    return edge_subsets(clusters, n)
