"""cGES — Circular (ring-distributed) GES.  Paper Algorithm 1.

Stages:
  1. Edge partitioning (partition.partition_edges) — once, up-front.
  2. Ring learning: k processes; per round, process i fuses its model with its
     ring predecessor's model (both from the previous round — one-hop
     information flow per round, exactly Figure 1) and runs GES restricted to
     its edge subset E_i, optionally capped at (10/k)*sqrt(n) insertions
     (cGES-L).
  3. Convergence: stop when no process improves on the best BDeu seen so far.
  4. Fine-tuning: one unrestricted GES (FES+BES) from the winner — this pass
     is what carries GES's theoretical guarantees over to cGES.

Engines:
  * engine="host": processes run as host tasks whose scoring sweeps are
    jit-batched (the faithful paper path; on a multi-device mesh the k tasks
    are dispatched concurrently by the ring executor in core/ring.py).
  * engine="jax": each process's GES is the fully-compiled ges_jit program —
    the building block the shard_map ring uses on device meshes.
  * engine="async": the asynchronous double-buffered ring
    (``core/ring_async.py``): k members run concurrently (threads here; the
    multi-process launcher is ``launch/ring_async_run.py``), each sweeping
    with ges_jit, exchanging BNs over sockets the moment a sweep finishes,
    with a circulating convergence token instead of a per-round barrier.
    Healthy runs follow the lockstep trajectory exactly; the engine also
    survives member death mid-run (elastic re-partition).

Both engines rescore exclusively through the unified sweep engine
(``core/sweeps.sweep``) and honour ``GESConfig.counts_impl``; with a fused
impl ("fused" / "fused_pallas") every column a ring process scores is fused:
insert columns are ONE joint contraction over the candidates
(bdeu.fused_insert_scores), and delete columns are ONE family-table build
marginalized per parent slot (bdeu.fused_delete_scores) — instead of one
table build per candidate in either phase.  BOTH engines sweep W-wide: the
host engine gathers each column down to its ``pids`` subset before scoring,
and ``engine="jax"`` passes each process's static (n, W) pid_table
(partition.pid_tables) into the compiled ges_jit while_loop, so the
fixed-shape program's per-round cost also tracks W = |E_i|, not n — the
constant factor that is decisive for the paper's n ~ 1000 workloads.  The
unrestricted fine-tuning pass stays full-n by construction (E = all edges).

Fusion goes through the unified layer in ``core/fusion.py``:
``fusion_engine`` picks the host (numpy) or traceable (jit) implementation
of the sigma-consistent edge union — adjacency-for-adjacency identical, so
the knob is purely a performance choice; ``None`` defaults from the
``REPRO_FUSION_ENGINE`` env var (mirroring ``REPRO_COUNTS_IMPL``) and
unknown values fail loudly up-front.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional

import numpy as np
import jax.numpy as jnp

from . import bdeu, fusion, partition
from .ges import (DeviceFamilyCache, GESConfig, GESResult, ScoreCache,
                  ges_host, ges_jit)


@dataclasses.dataclass
class CGESResult:
    adj: np.ndarray
    score: float
    rounds: int
    n_score_evals: int
    wall_time_s: float
    ring_scores: List[float]          # best score per round (trace)
    edge_masks: np.ndarray            # (k, n, n) partition actually used
    # wall time a k-worker deployment would see: ring rounds cost
    # max-over-processes (they run concurrently), partition+fine-tune serial.
    # (this container is 1-core, so the k processes run serially here; the
    # paper's Table 2c numbers are 8-thread wall times.)
    parallel_wall_s: float = 0.0
    # hits/misses/hit_rate of the persistent family-score cache, when
    # config.family_cache was on (host engine: the shared DeviceFamilyCache;
    # jax engine: summed per-member cache counters); None otherwise.
    family_cache_stats: Optional[dict] = None


def edge_add_limit(n: int, k: int) -> int:
    """cGES-L limit: (10 / k) * sqrt(n), at least 1."""
    return max(1, int(round((10.0 / k) * math.sqrt(n))))


def cges(
    data: np.ndarray,
    arities: np.ndarray,
    k: int = 4,
    limit: bool = True,
    config: Optional[GESConfig] = None,
    engine: str = "host",
    max_rounds: int = 50,
    edge_masks: Optional[np.ndarray] = None,
    seed_partition_ess: Optional[float] = None,
    fusion_engine: Optional[str] = None,
) -> CGESResult:
    t0 = time.perf_counter()
    m, n = data.shape
    k = int(k)
    if engine not in ("host", "jax", "async"):
        # Validate up front: an unknown engine used to silently run the host
        # path (the pre-PR 3 counts_impl fallthrough bug, lint rule R004).
        raise ValueError(
            f"cges: unknown engine {engine!r} "
            f"(valid: 'host', 'jax', 'async')")
    # built per call, not bound at import — honours REPRO_COUNTS_IMPL set
    # after ``import repro`` (see GESConfig.counts_impl)
    config = config if config is not None else GESConfig()
    # Resolve up-front so a typo'd engine (arg or REPRO_FUSION_ENGINE) fails
    # loudly before any learning work starts.
    fusion_engine = fusion.resolve_fusion_engine(fusion_engine)

    # ---- Stage 1: edge partitioning --------------------------------------
    if edge_masks is None:
        edge_masks = partition.partition_edges(
            data, arities, k,
            ess=(seed_partition_ess or config.ess),
            engine="fast",
        )
    add_limit = edge_add_limit(n, k) if limit else None
    parallel_wall = time.perf_counter() - t0          # stage 1 is serial

    graphs = [np.zeros((n, n), dtype=np.int8) for _ in range(k)]
    best_score = -np.inf
    best_adj = np.zeros((n, n), dtype=np.int8)
    evals = 0
    ring_scores: List[float] = []
    # the paper's shared 'concurrent safe data structure': one score cache
    # shared by every ring process across every round
    cache = ScoreCache()
    # Persistent device-resident family-score caches (config.family_cache):
    # the host engine shares ONE DeviceFamilyCache handle across all k
    # processes, every round AND the fine-tune (full-n scattered columns,
    # scope-worded); the jax engine keeps one per-process cache pytree whose
    # warmed state is fed back into the next round's ges_jit call.
    dev_cache = (DeviceFamilyCache(n, config.cache_capacity)
                 if (config.family_cache and engine == "host") else None)
    jax_caches: List = [None] * k

    data_j = jnp.asarray(data.astype(np.int32))
    ar_j = jnp.asarray(arities.astype(np.int32))
    r_max = int(arities.max())
    # Static per-process E_i candidate tables (one shared W so all k
    # processes reuse ONE compiled ges_jit program): the compiled engine
    # sweeps W-wide end-to-end, mirroring the host engine's pids gather.
    pid_j = (jnp.asarray(partition.pid_tables(edge_masks))
             if engine == "jax" else None)

    # ---- Stage 2: ring learning ------------------------------------------
    if engine == "async":
        # concurrent members + circulating convergence token replace the
        # lockstep round loop below; healthy trajectories are identical
        from . import ring_async
        ring = ring_async.run_ring_async_threads(
            data, arities, edge_masks, config=config,
            add_limit=add_limit, max_rounds=max_rounds)
        rounds = int(ring["rounds"])
        ring_scores = [float(s) for s in ring["ring_scores"]]
        best_adj = np.asarray(ring["best_adj"], dtype=np.int8)
        best_score = float(ring["best_score"])
        evals += int(ring["n_score_evals"])
        # a real k-process deployment's ring wall time is the slowest
        # member's own busy+blocked span, not this 1-core serialization
        parallel_wall += max(
            sum(float(np.sum(results_i["timings"][ph]))
                for ph in ("wait_us", "fuse_us", "sweep_us"))
            for results_i in (ring["members"][i] for i in ring["survivors"])
        ) / 1e6
        return _finish_cges(
            data, arities, data_j, ar_j, r_max, best_adj,
            config, engine, cache, dev_cache, jax_caches, evals,
            rounds, ring_scores, edge_masks, parallel_wall, t0)

    rounds = 0
    go = True
    while go and rounds < max_rounds:
        new_graphs: List[np.ndarray] = []
        new_scores: List[float] = []
        proc_walls: List[float] = []
        for i in range(k):
            tp = time.perf_counter()
            pred = graphs[(i - 1) % k]
            if rounds == 0:
                init = np.zeros((n, n), dtype=np.int8)
            else:
                init = fusion.fusion_edge_union(
                    graphs[i], pred, engine=fusion_engine).astype(np.int8)
            if engine == "jax":
                out = ges_jit(
                    data_j, ar_j, jnp.asarray(init),
                    jnp.asarray(edge_masks[i].astype(np.int8)),
                    add_limit=add_limit, config=config, r_max=r_max,
                    pid_table=pid_j[i], cache=jax_caches[i],
                    return_cache=config.family_cache)
                if config.family_cache:
                    adj_i, score_i, n_ins, n_del, jax_caches[i] = out
                else:
                    adj_i, score_i, n_ins, n_del = out
                adj_i = np.asarray(adj_i)
                score_i = float(score_i)
                W = int(pid_j.shape[2])
                evals += W * n + W * (int(n_ins) + int(n_del))
            else:
                res = ges_host(data, arities, init_adj=init,
                               allowed=edge_masks[i], add_limit=add_limit,
                               config=config, cache=cache,
                               family_cache=dev_cache)
                adj_i, score_i = res.adj, res.score
                evals += res.n_score_evals
            new_graphs.append(adj_i)
            new_scores.append(score_i)
            proc_walls.append(time.perf_counter() - tp)
        graphs = new_graphs
        rounds += 1
        parallel_wall += max(proc_walls)   # ring processes run concurrently

        # ---- convergence check (Algorithm 1 lines 11-16) ------------------
        round_best = max(new_scores)
        ring_scores.append(round_best)
        if round_best > best_score + config.tol:
            best_score = round_best
            best_adj = graphs[int(np.argmax(new_scores))].copy()
            go = True
        else:
            go = False

    return _finish_cges(
        data, arities, data_j, ar_j, r_max, best_adj,
        config, engine, cache, dev_cache, jax_caches, evals,
        rounds, ring_scores, edge_masks, parallel_wall, t0)


def _finish_cges(data, arities, data_j, ar_j, r_max, best_adj,
                 config, engine, cache, dev_cache, jax_caches, evals,
                 rounds, ring_scores, edge_masks, parallel_wall,
                 t0) -> CGESResult:
    """Stage 3 (unrestricted fine-tuning GES from the ring winner) plus
    result assembly — shared by the lockstep round loop and the async-ring
    engine.  The compiled engines ("jax", "async") fine-tune with ges_jit;
    the host engine reuses its shared caches."""
    n = data.shape[1]
    t_ft = time.perf_counter()
    if engine in ("jax", "async"):
        adj_f, score_f, n_ins, n_del = ges_jit(
            data_j, ar_j, jnp.asarray(best_adj.astype(np.int8)),
            jnp.ones((n, n), dtype=jnp.int8),
            add_limit=None, config=config, r_max=r_max)
        final_adj = np.asarray(adj_f)
        final_score = float(score_f)
        evals += n * n + n * (int(n_ins) + int(n_del))
    else:
        res = ges_host(data, arities, init_adj=best_adj, allowed=None,
                       add_limit=None, config=config, cache=cache,
                       family_cache=dev_cache)
        final_adj, final_score = res.adj, res.score
        evals += res.n_score_evals

    parallel_wall += time.perf_counter() - t_ft       # fine-tune is serial
    fc_stats = None
    if dev_cache is not None:
        fc_stats = dev_cache.stats()
    elif config.family_cache and engine == "jax":
        hits = sum(int(c.hits) for c in jax_caches if c is not None)
        misses = sum(int(c.misses) for c in jax_caches if c is not None)
        fc_stats = {"hits": hits, "misses": misses,
                    "hit_rate": hits / max(hits + misses, 1)}
    return CGESResult(
        adj=final_adj, score=final_score, rounds=rounds,
        n_score_evals=evals, wall_time_s=time.perf_counter() - t0,
        ring_scores=ring_scores, edge_masks=edge_masks,
        parallel_wall_s=parallel_wall, family_cache_stats=fc_stats,
    )
