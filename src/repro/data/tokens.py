"""Deterministic, shardable, resumable LM token pipeline.

Stateless in the step index: ``batch_at(step)`` folds the step into the PRNG
key, so (a) restart-at-step-s replays *identical* batches with no pipeline
state to checkpoint, and (b) any host can materialize any shard of any step
independently (multi-host data loading without coordination).

Two sources:
* ``synthetic_zipf`` — Zipf-distributed ids (vocab statistics of web text);
* ``markov``        — an order-1 Markov chain with a learnable structure, so
  a training run has an actual signal to fit (loss decreases measurably).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    kind: str = "markov"          # markov | synthetic_zipf
    seed: int = 0
    zipf_a: float = 1.2
    markov_states: int = 64       # transition-structure richness


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._base = jax.random.PRNGKey(cfg.seed)
        if cfg.kind == "markov":
            # fixed random transition matrix with sharp rows (learnable)
            rng = np.random.default_rng(cfg.seed + 1)
            k = min(cfg.markov_states, cfg.vocab)
            t = rng.dirichlet(np.full(k, 0.05), size=k)
            self._trans = jnp.asarray(np.log(t + 1e-9), dtype=jnp.float32)
            self._proj = jnp.asarray(
                rng.integers(0, k, size=cfg.vocab), dtype=jnp.int32)

    def batch_at(self, step: int) -> dict:
        """{tokens, labels}: labels = tokens shifted left (next-token LM)."""
        cfg = self.cfg
        key = jax.random.fold_in(self._base, step)
        if cfg.kind == "synthetic_zipf":
            u = jax.random.uniform(key, (cfg.global_batch, cfg.seq_len + 1),
                                   minval=1e-6, maxval=1.0)
            ranks = jnp.floor(u ** (-1.0 / (cfg.zipf_a - 1.0))) % cfg.vocab
            seq = ranks.astype(jnp.int32)
        else:
            k = self._trans.shape[0]
            keys = jax.random.split(key, cfg.seq_len + 2)
            s0 = jax.random.randint(keys[0], (cfg.global_batch,), 0, k)

            def step_fn(s, kk):
                g = jax.random.gumbel(kk, (cfg.global_batch, k))
                nxt = jnp.argmax(self._trans[s] + g, axis=-1)
                return nxt, nxt

            _, states = jax.lax.scan(step_fn, s0, keys[1:])
            states = jnp.moveaxis(states, 0, 1)       # (B, T+1)
            # lift hidden states to vocab ids deterministically-with-noise
            lift = jax.random.randint(keys[0], states.shape, 0,
                                      max(1, self.cfg.vocab // k))
            seq = (states * (self.cfg.vocab // k) + lift).astype(jnp.int32)
            seq = jnp.clip(seq, 0, cfg.vocab - 1)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def shard_of(self, step: int, proc: int, n_procs: int) -> dict:
        """Host-local shard (multi-host loading): rows proc::n_procs."""
        full = self.batch_at(step)
        return jax.tree.map(lambda a: a[proc::n_procs], full)
