"""Bayesian networks with explicit CPTs + vectorized forward sampling.

The paper samples 11 datasets x 5000 instances from the three largest
discrete bnlearn networks (link: n=724, pigs: n=441, munin: n=1041).  Those
network files are not available offline, so this module provides

* a CPT-backed BN container with exact forward sampling (vectorized per
  topological level: all instances sampled simultaneously via a Gumbel-max
  draw over CPT rows), and
* generators for *family-matched* synthetic networks — ``link_like``,
  ``pigs_like``, ``munin_like`` — that reproduce each domain's structural
  statistics (node count, edge/node ratio, max in-degree, arity profile) at a
  configurable scale factor so the paper's Tables 2a-2c can be exercised at
  CPU-tractable sizes and, with scale=1.0, at full paper scale.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core.dag import random_dag_np, topological_order_np


@dataclasses.dataclass
class BayesianNetwork:
    adj: np.ndarray                 # (n, n) bool, adj[x, y]: x -> y
    arities: np.ndarray             # (n,) int
    cpts: List[np.ndarray]          # cpts[i]: (q_i, r_i) rows sum to 1
    parent_lists: List[np.ndarray]  # cpts[i] row index = radix code over these

    @property
    def n(self) -> int:
        return self.adj.shape[0]

    def logprob(self, data: np.ndarray) -> np.ndarray:
        """Exact log P(x) per instance (vectorized)."""
        m = data.shape[0]
        lp = np.zeros(m, dtype=np.float64)
        for i in range(self.n):
            cfg = np.zeros(m, dtype=np.int64)
            for p in self.parent_lists[i]:
                cfg = cfg * int(self.arities[p]) + data[:, p]
            lp += np.log(self.cpts[i][cfg, data[:, i]] + 1e-300)
        return lp


def random_bn(
    rng: np.random.Generator,
    n: int,
    n_edges: int,
    arity_choices=(2, 3),
    arity_probs=None,
    max_parents: int = 5,
    concentration: float = 0.5,
) -> BayesianNetwork:
    """Random DAG + Dirichlet CPTs.  Low ``concentration`` -> sharp CPTs ->
    strong, learnable dependencies (the regime of the paper's domains)."""
    adj = random_dag_np(rng, n, n_edges, max_parents=max_parents)
    arities = rng.choice(np.asarray(arity_choices), p=arity_probs, size=n).astype(np.int64)
    cpts, plists = [], []
    for i in range(n):
        parents = np.flatnonzero(adj[:, i])
        q = int(np.prod(arities[parents])) if parents.size else 1
        r = int(arities[i])
        cpt = rng.dirichlet(np.full(r, concentration), size=q)
        cpts.append(cpt)
        plists.append(parents)
    return BayesianNetwork(adj=adj, arities=arities, cpts=cpts, parent_lists=plists)


def forward_sample(
    bn: BayesianNetwork, m: int, rng: np.random.Generator
) -> np.ndarray:
    """Vectorized ancestral sampling: one Gumbel-max draw per (instance, node),
    nodes processed in topological order, all instances at once."""
    n = bn.n
    data = np.zeros((m, n), dtype=np.int32)
    order = topological_order_np(bn.adj)
    gumbel = rng.gumbel(size=(m, int(bn.arities.max())))
    for v in order:
        parents = bn.parent_lists[v]
        cfg = np.zeros(m, dtype=np.int64)
        for p in parents:
            cfg = cfg * int(bn.arities[p]) + data[:, p]
        probs = bn.cpts[v][cfg]                      # (m, r_v)
        g = rng.gumbel(size=probs.shape)
        data[:, v] = np.argmax(np.log(probs + 1e-300) + g, axis=1)
    return data


# ---------------------------------------------------------------------------
# Family-matched synthetic stand-ins for the paper's domains
# ---------------------------------------------------------------------------
# Structural statistics of the bnlearn originals:
#   link : n=724,  e=1125, max_pa=3, arities mostly 2-4
#   pigs : n=441,  e=592,  max_pa=2, arities 3
#   munin: n=1041, e=1397, max_pa=3, arities 1-21 (median ~4)

BENCHMARK_FAMILIES: Dict[str, dict] = {
    "link_like": dict(n=724, edge_ratio=1125 / 724, max_parents=3,
                      arity_choices=(2, 3, 4), arity_probs=(0.6, 0.3, 0.1)),
    "pigs_like": dict(n=441, edge_ratio=592 / 441, max_parents=2,
                      arity_choices=(3,), arity_probs=(1.0,)),
    "munin_like": dict(n=1041, edge_ratio=1397 / 1041, max_parents=3,
                       arity_choices=(2, 3, 4, 5), arity_probs=(0.3, 0.3, 0.25, 0.15)),
}


def benchmark_bn(
    family: str, scale: float = 1.0, seed: int = 0
) -> BayesianNetwork:
    """A family-matched network, optionally scaled down (scale in (0, 1])."""
    spec = BENCHMARK_FAMILIES[family]
    rng = np.random.default_rng(seed)
    n = max(8, int(round(spec["n"] * scale)))
    n_edges = int(round(n * spec["edge_ratio"]))
    return random_bn(
        rng, n, n_edges,
        arity_choices=spec["arity_choices"],
        arity_probs=spec["arity_probs"],
        max_parents=spec["max_parents"],
        concentration=0.4,
    )
