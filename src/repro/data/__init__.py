from .bn import BayesianNetwork, random_bn, forward_sample, BENCHMARK_FAMILIES
