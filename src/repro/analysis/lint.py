"""Pass 1 — repo-specific AST lint.

Four rules, each encoding a bug class this repo has actually shipped and
fixed at least once (see ``repro.analysis`` package docstring for the full
catalog with PR references):

* **R001** — import-time ``os.environ`` reads of ``REPRO_*`` / ``RING_*``
  config names at module level.  Env-driven config must be read at call
  time (function body, or a ``default_factory`` lambda) so setting the
  variable after ``import repro`` is honoured.
* **R002** — bare ``assert`` validating caller-supplied values in
  ``core/``, ``kernels/`` or ``models/``.  Asserts vanish under
  ``python -O``; shape/divisibility contracts must raise ``ValueError``.
* **R003** — class-body defaults (dataclass fields or plain class
  attributes) whose default expression reads the environment — the value
  binds once at class creation, silently freezing the env.
* **R004** — engine/backend dispatch chains (>= 2 ``X == "literal"``
  branches on a ``counts_impl`` / ``engine`` / ``impl``-style variable)
  whose final ``else`` silently falls through instead of raising, in a
  function with no up-front validator call (``check_*`` / ``single_impl``
  / ``resolve_*``).

Suppression: append ``# repro: allow=R002`` (comma-separated rule ids, or
``allow=all``) to the flagged line or the line directly above it.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Set

from .findings import Finding

ENV_NAME_RE = re.compile(r"^(REPRO_|RING_)")
DISPATCH_VAR_RE = re.compile(
    r"(counts_impl|fusion_engine|engine|impl|backend)$")
VALIDATOR_RE = re.compile(r"^_?(check_\w+|single_impl|resolve_\w+)$")
SUPPRESS_RE = re.compile(r"#\s*repro:\s*allow=([A-Za-z0-9,_ ]+)")

# R002 applies to the packages whose entry points take caller-supplied
# shapes/ids; launch/ and benchmark drivers may assert on their own state.
R002_PACKAGES = ("core", "kernels", "models")

RULES = ("R001", "R002", "R003", "R004")


def _suppressed(lines: Sequence[str], lineno: int) -> Set[str]:
    """Rule ids allowed at 1-based ``lineno`` (same line or the line above)."""
    out: Set[str] = set()
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines):
            m = SUPPRESS_RE.search(lines[ln - 1])
            if m:
                out |= {t.strip().upper() for t in m.group(1).split(",")}
    return out


def _env_read_key(node: ast.AST) -> Optional[str]:
    """The env-var name if ``node`` is an environment READ, else None.

    Matches ``os.environ.get(k, ...)``, ``os.getenv(k, ...)`` and
    ``os.environ[k]`` in Load context.  Writes (``os.environ[k] = v``) are
    not reads — the launch/ modules mutate XLA_FLAGS legitimately.
    Returns "" when the read's key is not a string literal (unknown name).
    """
    def attr_is(n, *path):
        for name in reversed(path[1:]):
            if not (isinstance(n, ast.Attribute) and n.attr == name):
                return False
            n = n.value
        return isinstance(n, ast.Name) and n.id == path[0]

    key_node = None
    if isinstance(node, ast.Call):
        if attr_is(node.func, "os", "environ", "get") or \
                attr_is(node.func, "os", "getenv"):
            key_node = node.args[0] if node.args else None
        else:
            return None
    elif isinstance(node, ast.Subscript) and \
            isinstance(node.ctx, ast.Load) and \
            attr_is(node.value, "os", "environ"):
        key_node = node.slice
    else:
        return None
    if isinstance(key_node, ast.Constant) and isinstance(key_node.value, str):
        return key_node.value
    return ""


def _import_time_env_reads(root: ast.AST, include_self: bool = True):
    """(node, key) env reads in ``root`` that execute at import time.

    Function/lambda BODIES are call-time and skipped; function decorators
    and argument defaults evaluate at def time and are scanned.  Class
    bodies are scanned too (callers scope them to R001 vs R003).
    """
    out = []

    def walk(node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for sub in node.decorator_list + node.args.defaults + \
                    [d for d in node.args.kw_defaults if d is not None]:
                walk(sub)
            return              # the body is call-time context
        if isinstance(node, ast.Lambda):
            return              # call-time context — the default_factory idiom
        key = _env_read_key(node)
        if key is not None:
            out.append((node, key))
        for child in ast.iter_child_nodes(node):
            walk(child)

    if include_self:
        walk(root)
    else:
        for child in ast.iter_child_nodes(root):
            walk(child)
    return out


class _Linter:
    def __init__(self, source: str, path: str, rules: Iterable[str]):
        self.source = source
        self.path = path
        self.lines = source.splitlines()
        self.rules = set(rules)
        self.findings: List[Finding] = []

    def report(self, rule: str, node: ast.AST, message: str) -> None:
        lineno = getattr(node, "lineno", 0)
        allowed = _suppressed(self.lines, lineno)
        if rule in self.rules and rule not in allowed and "ALL" not in allowed:
            snippet = (self.lines[lineno - 1].strip()
                       if 1 <= lineno <= len(self.lines) else None)
            self.findings.append(
                Finding(rule, self.path, lineno, message, snippet))

    # ---- R001: import-time env reads of repo config names ---------------

    def check_r001(self, tree: ast.Module) -> None:
        for node in ast.iter_child_nodes(tree):
            if isinstance(node, ast.ClassDef):
                continue        # class bodies are R003's scope
            for read, key in _import_time_env_reads(node):
                if ENV_NAME_RE.match(key or ""):
                    self.report(
                        "R001", read,
                        f"import-time os.environ read of {key!r}: the value "
                        f"binds at `import repro` and setting the variable "
                        f"afterwards is silently ignored — read it at call "
                        f"time (function body / default_factory), like "
                        f"GESConfig.counts_impl")

    # ---- R003: class-creation-time env capture in defaults ---------------

    def check_r003(self, tree: ast.Module) -> None:
        for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
            for stmt in cls.body:
                value = None
                if isinstance(stmt, ast.AnnAssign):
                    value = stmt.value
                elif isinstance(stmt, ast.Assign):
                    value = stmt.value
                if value is None:
                    continue
                for read, key in _import_time_env_reads(value):
                    self.report(
                        "R003", read,
                        f"class-body default reads os.environ"
                        f"{f' ({key!r})' if key else ''}: the env state is "
                        f"captured once at class creation — use "
                        f"dataclasses.field(default_factory=lambda: ...) so "
                        f"each instantiation re-reads it")

    # ---- R002: bare asserts on caller-supplied values ---------------------

    def _tainted_names(self, fn: ast.FunctionDef) -> Set[str]:
        """Parameter names plus locals (transitively) derived from them."""
        args = fn.args
        tainted: Set[str] = {
            a.arg for a in (args.posonlyargs + args.args + args.kwonlyargs)}
        for extra in (args.vararg, args.kwarg):
            if extra is not None:
                tainted.add(extra.arg)

        def names_in(node) -> Set[str]:
            return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}

        def visit_assigns(node):
            changed = False
            for stmt in ast.walk(node):
                targets = []
                if isinstance(stmt, ast.Assign):
                    targets, value = stmt.targets, stmt.value
                elif isinstance(stmt, ast.AnnAssign) and stmt.value:
                    targets, value = [stmt.target], stmt.value
                else:
                    continue
                if not names_in(value) & tainted:
                    continue
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name) and n.id not in tainted:
                            tainted.add(n.id)
                            changed = True
            return changed

        while visit_assigns(fn):    # fixed point; function bodies are tiny
            pass
        return tainted

    def check_r002(self, tree: ast.Module) -> None:
        parts = Path(self.path).parts
        if not any(p in parts for p in R002_PACKAGES):
            return
        for fn in [n for n in ast.walk(tree)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
            tainted = self._tainted_names(fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Assert):
                    continue
                used = {n.id for n in ast.walk(node.test)
                        if isinstance(n, ast.Name)}
                if used & tainted:
                    self.report(
                        "R002", node,
                        f"bare assert validates caller-supplied values "
                        f"({', '.join(sorted(used & tainted))}) in "
                        f"{fn.name}(): asserts vanish under `python -O` — "
                        f"raise ValueError with a named message instead")

    # ---- R004: silent engine-dispatch fallthrough -------------------------

    @staticmethod
    def _chain_var(test: ast.AST) -> Optional[str]:
        """Dispatch variable name if ``test`` is ``X == "lit"`` or
        ``X in ("lit", ...)`` on a plain Name; else None."""
        if not (isinstance(test, ast.Compare) and len(test.ops) == 1
                and isinstance(test.left, ast.Name)):
            return None
        op, comp = test.ops[0], test.comparators[0]
        if isinstance(op, ast.Eq):
            ok = isinstance(comp, ast.Constant) and \
                isinstance(comp.value, str)
        elif isinstance(op, ast.In):
            ok = isinstance(comp, (ast.Tuple, ast.List, ast.Set)) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, str)
                for e in comp.elts)
        else:
            ok = False
        return test.left.id if ok else None

    @staticmethod
    def _has_validator_call(scope: ast.AST) -> bool:
        for node in ast.walk(scope):
            if isinstance(node, ast.Call):
                fname = None
                if isinstance(node.func, ast.Name):
                    fname = node.func.id
                elif isinstance(node.func, ast.Attribute):
                    fname = node.func.attr
                if fname and VALIDATOR_RE.match(fname):
                    return True
        return False

    def check_r004(self, tree: ast.Module) -> None:
        # map each If to its parent so elif links aren't double-counted
        elif_children = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.If) and len(node.orelse) == 1 and \
                    isinstance(node.orelse[0], ast.If):
                elif_children.add(id(node.orelse[0]))
        # nearest top-level function scope for validator lookups
        scopes = {}

        def assign_scope(node, scope):
            for child in ast.iter_child_nodes(node):
                s = scope
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    s = scope if scope is not None else child
                scopes[id(child)] = s
                assign_scope(child, s)

        assign_scope(tree, None)

        for node in ast.walk(tree):
            if not isinstance(node, ast.If) or id(node) in elif_children:
                continue
            var = self._chain_var(node.test)
            if var is None or not DISPATCH_VAR_RE.search(var):
                continue
            # walk the elif ladder
            chain, cur = [node], node
            while len(cur.orelse) == 1 and isinstance(cur.orelse[0], ast.If):
                nxt = cur.orelse[0]
                if self._chain_var(nxt.test) != var:
                    break
                chain.append(nxt)
                cur = nxt
            if len(chain) < 2:
                continue        # single-branch ifs are not dispatch chains
            tail = chain[-1].orelse
            if tail and any(isinstance(s, ast.Raise) for s in tail):
                continue        # loud fallthrough — exactly what we want
            scope = scopes.get(id(node))
            if scope is not None and self._has_validator_call(scope):
                continue        # values pre-validated (check_*/single_impl)
            self.report(
                "R004", node,
                f"dispatch chain on {var!r} "
                f"{'has a silent else' if tail else 'has no else'}: an "
                f"unknown value silently runs the fallback backend (the "
                f"pre-PR 3 counts_impl bug) — raise ValueError in the else "
                f"or validate {var!r} up front (check_* / single_impl)")


def lint_source(source: str, path: str = "<string>",
                rules: Iterable[str] = RULES) -> List[Finding]:
    """Lint one source text; ``path`` anchors findings and scopes R002."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("R000", path, e.lineno or 0,
                        f"syntax error: {e.msg}")]
    linter = _Linter(source, path, rules)
    linter.check_r001(tree)
    linter.check_r002(tree)
    linter.check_r003(tree)
    linter.check_r004(tree)
    linter.findings.sort(key=lambda f: (f.line, f.rule))
    return linter.findings


def lint_paths(paths: Iterable[str],
               rules: Iterable[str] = RULES) -> List[Finding]:
    """Lint every ``*.py`` under the given files/directories."""
    findings: List[Finding] = []
    for p in paths:
        root = Path(p)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            findings.extend(
                lint_source(f.read_text(encoding="utf-8"), str(f), rules))
    return findings
