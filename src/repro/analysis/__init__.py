"""repro.analysis — the repo's static-analysis layer: a three-pass checker
(`python -m repro.analysis`) that turns this codebase's recurring bug
classes into machine-enforced invariants.  Exit status is nonzero on any
finding, ``--json`` emits a structured report, and CI runs it as a
blocking gate.

Rule catalog — every id encodes a bug this repo actually shipped
=================================================================

**Pass 1 — AST lint** (:mod:`repro.analysis.lint`)

``R001`` *import-time env read of ``REPRO_*`` / ``RING_*`` config.*
    History: ``GESConfig.counts_impl`` was a plain dataclass default bound
    at class creation, so ``REPRO_COUNTS_IMPL`` set after ``import repro``
    was silently ignored (fixed in PR 5 with the ``default_factory``
    pattern); the same import-time binding then survived in
    ``core/ring_async.py``'s ``RING_ASYNC_DEBUG`` until this PR.  Config
    env vars must be read at call time.

``R002`` *bare ``assert`` validating caller-supplied values in ``core/``,
    ``kernels/`` or ``models/``.*  History: ``ring_cges``'s k-mismatch
    assert vanished under ``python -O`` and resurfaced as an opaque
    shard_map shape error (named ``ValueError`` since PR 7) — but every
    kernel package still guarded its tile-divisibility contracts with
    asserts until this PR.  Shape/argument contracts must raise
    ``ValueError`` so they survive optimized mode (CI runs a
    ``python -O`` smoke leg to prove it).

``R003`` *class-body defaults capturing env state at class creation.*
    The dataclass-shaped special case of R001 (the exact pre-PR 5
    ``GESConfig`` bug): a field default like ``x: str =
    os.environ.get(...)`` evaluates once when the class is created.  Use
    ``dataclasses.field(default_factory=lambda: ...)``.

``R004`` *silent engine-dispatch fallthrough.*  History: before PR 3 an
    unknown ``counts_impl`` silently dispatched to the segment engine, so
    a typo'd backend ran the wrong code with no error.  A chain of
    >= 2 ``X == "literal"`` branches on a dispatch variable
    (``counts_impl`` / ``engine`` / ``fusion_engine`` / ``impl`` /
    ``backend``) must either raise in its ``else`` or sit in a function
    that validates up front (``check_*`` / ``single_impl`` /
    ``resolve_*`` — how ``core/bdeu.py``'s chains stay legal).

Suppression: ``# repro: allow=R002`` (comma-separated ids, or
``allow=all``) on the flagged line or the line directly above.

**Pass 2 — trace contracts** (:mod:`repro.analysis.contracts`)

Walks the jaxprs of the REAL programs — ``sweep`` on all three backends,
``ges_jit_body`` (full-n / restricted / cached), the restricted (W, n)
ring program, ``fusion.fuse_trace``, ``score_cache.lookup_or_compute``:

``C001``  every collective (psum/ppermute/pmax/all_gather/axis_index)
          names a mesh-declared axis.
``C002``  ``lax.while_loop`` carries are fixed — shape, dtype and
          weak-type identical between loop input and body output.
``C003``  no float64/complex128 aval anywhere in the eqn graph.
``C004``  each ``data_axis_name`` count path rebuilds its global table
          with EXACTLY one psum (the additive-counts contract of PR 6).
``C005``  zero re-traces across steady-state same-shape rounds of the
          jitted sweep / ges_jit / ring programs (compilation-cache pin).

**Pass 3 — VMEM budgets** (:mod:`repro.analysis.vmem`)

Symbolic per-kernel VMEM footprints from the same tile/grid parameters the
kernels take, gated against a ~16 MiB/core TPU budget — so a config that
would only fail at TPU compile time at paper scale fails here first.
Repo-default paper-scale table (max_q=4096, compiled r_pad=128,
munin-scale k_pad=1152; ``V001`` on overflow):

==================  ==========  ====================================
kernel              footprint   dominant term
==================  ==========  ====================================
bdeu_count           6.13 MiB   (tile_m, max_q) one-hot slab
bdeu_sweep          12.32 MiB   (max_q, tile_n*r_max) counts block x2
bdeu_delete         12.26 MiB   one-hots + (max_q, r_pad) table x2
flash_attention      0.81 MiB   (BQ, BK) logits/probs pair
ssd_scan             0.66 MiB   (Q, Q) decay mask
==================  ==========  ====================================

CLI
===

``python -m repro.analysis [paths] [--json] [--skip-lint]
[--skip-contracts] [--skip-vmem] [--fast] [--vmem-budget BYTES]``

Default paths: ``src/`` (resolved relative to the repo root).  The
contracts pass forces extra host devices (like ``launch/dryrun``) so the
ring program traces at k = 2 with a data axis even on CPU CI.
"""
from .findings import Finding, Report
from .lint import RULES, lint_paths, lint_source
from .vmem import (DEFAULT_BUDGET, DEFAULT_CONFIGS, VMEM_BUDGETS,
                   check_config, footprint, run_vmem_checks)

__all__ = [
    "Finding", "Report", "RULES", "lint_paths", "lint_source",
    "DEFAULT_BUDGET", "DEFAULT_CONFIGS", "VMEM_BUDGETS", "check_config",
    "footprint", "run_vmem_checks",
]
