"""Finding model shared by every analysis pass.

A finding is one violation of a machine-enforced invariant: a lint rule hit
(``R0xx``), a trace-contract breach (``C0xx``) or a VMEM budget overflow
(``V0xx``).  Findings serialize to the ``--json`` report and drive the CLI
exit code (any finding => nonzero), so CI can gate on them.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str                 # "R001" | ... | "C001" | ... | "V001"
    path: str                 # file (lint) or program name (contracts/vmem)
    line: int                 # 1-based source line; 0 when not file-anchored
    message: str
    snippet: Optional[str] = None   # the offending source line, stripped

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        out = f"{loc}: {self.rule} {self.message}"
        if self.snippet:
            out += f"\n    {self.snippet}"
        return out

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Report:
    """Aggregated result of the passes that actually ran."""
    findings: List[Finding] = dataclasses.field(default_factory=list)
    # Pass-specific informational payloads (psum counts, retrace counters,
    # per-kernel VMEM footprints) — recorded even when everything passes so
    # the JSON report doubles as a budget/contract snapshot.
    info: dict = dataclasses.field(default_factory=dict)
    passes_run: List[str] = dataclasses.field(default_factory=list)

    def extend(self, findings: List[Finding]) -> None:
        self.findings.extend(findings)

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> str:
        return json.dumps({
            "ok": self.ok,
            "passes_run": self.passes_run,
            "n_findings": len(self.findings),
            "findings": [f.to_dict() for f in self.findings],
            "info": self.info,
        }, indent=2, sort_keys=True)
