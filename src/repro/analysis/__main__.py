"""CLI entry: ``python -m repro.analysis`` — see the package docstring.

Exit codes: 0 = clean, 1 = findings, 2 = a pass crashed (still a gate
failure, but distinguishable in CI logs).
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback
from pathlib import Path


def _repo_src_default() -> str:
    """Default lint scope: the src/ tree this installed package lives in."""
    here = Path(__file__).resolve()
    src = here.parents[2]            # .../src/repro/analysis -> .../src
    return str(src if src.name == "src" else here.parents[1])


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific lint + jaxpr trace contracts + Pallas "
                    "VMEM budget gate (nonzero exit on any finding)")
    p.add_argument("paths", nargs="*", default=None,
                   help="files/dirs to lint (default: the repo's src/ tree)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit the structured JSON report")
    p.add_argument("--skip-lint", action="store_true")
    p.add_argument("--skip-contracts", action="store_true")
    p.add_argument("--skip-vmem", action="store_true")
    p.add_argument("--fast", action="store_true",
                   help="contracts: skip the (slower) steady-state "
                        "re-trace execution pin, keep the trace checks")
    p.add_argument("--vmem-budget", type=int, default=None,
                   help="VMEM budget in bytes (default: 16 MiB/core TPU)")
    p.add_argument("--rules", default=None,
                   help="comma-separated lint rule subset (e.g. R001,R004)")
    args = p.parse_args(argv)

    if not args.skip_contracts and "jax" not in sys.modules:
        # The ring-program contract wants k=2 ring slots (+ a data axis)
        # even on CPU — force host devices BEFORE jax initializes, exactly
        # like launch/dryrun.  Harmless when jax was already imported (the
        # checks degrade to k=1 on a single device).
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=8").strip()

    from .findings import Report
    report = Report()

    if not args.skip_lint:
        from .lint import RULES, lint_paths
        rules = (tuple(r.strip().upper() for r in args.rules.split(","))
                 if args.rules else RULES)
        paths = args.paths or [_repo_src_default()]
        report.extend(lint_paths(paths, rules))
        report.passes_run.append("lint")
        report.info["lint"] = {"paths": [str(p) for p in paths],
                               "rules": list(rules)}

    if not args.skip_contracts:
        from .contracts import run_contract_checks
        try:
            findings, info = run_contract_checks(
                check_retrace=not args.fast)
        except Exception:
            print(traceback.format_exc(), file=sys.stderr)
            return 2
        report.extend(findings)
        report.passes_run.append("contracts")
        report.info["contracts"] = info

    if not args.skip_vmem:
        from .vmem import DEFAULT_BUDGET, run_vmem_checks
        budget = args.vmem_budget or DEFAULT_BUDGET
        findings, info = run_vmem_checks(budget)
        report.extend(findings)
        report.passes_run.append("vmem")
        report.info["vmem"] = info

    if args.as_json:
        print(report.to_json())
    else:
        for f in report.findings:
            print(f.format())
        print(f"repro.analysis: {len(report.findings)} finding(s) across "
              f"{'+'.join(report.passes_run) or 'no passes'}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
