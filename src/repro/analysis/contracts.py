"""Pass 2 — jaxpr trace contracts over the REAL compiled programs.

The engine's correctness-by-construction claims ("one psum per count path",
"the whole GES loop compiles to one while_loop with a fixed carry", "no
re-traces in steady state") live only in docstrings until something walks
the jaxprs and checks them.  This pass traces the actual production
programs — ``sweep`` on all three backends, ``ges_jit_body``, the
restricted (W, n) ring program, ``fusion.fuse_trace`` and
``score_cache.lookup_or_compute`` — and verifies:

* **C001 collective-axis discipline** — every ``psum`` / ``ppermute`` /
  ``pmax`` / ``all_gather`` / ``axis_index`` equation names an axis the
  surrounding mesh declares; an unbound or misspelled axis name is a
  deploy-time crash on a bigger mesh.
* **C002 while-carry stability** — every ``lax.while_loop``'s carry avals
  are identical between loop input and body output (shape, dtype AND
  weak-type), so no promotion can leak through the compiled FES/BES loops.
* **C003 dtype discipline** — no float64/complex128 aval anywhere in the
  eqn graph (x64 creep silently doubles HBM traffic and breaks the
  all-f32 count-exactness argument).
* **C004 one-psum-per-count-path** — each count primitive under a data
  mesh axis (``local_score_masked`` per single backend,
  ``fused_insert_scores`` / ``fused_delete_scores`` per fused backend)
  contains EXACTLY one psum over that axis: zero means shard-local counts
  leak into the BDeu reduction, two means double-counted tables.
* **C005 steady-state re-trace pin** — running the jitted sweep / ges_jit
  / ring programs for several same-shape rounds must not grow their
  compilation caches (a re-trace at paper scale is minutes, not ms).

All checks run on tiny synthetic problems — the contracts are about the
trace/eqn structure, which is shape-generic.
"""
from __future__ import annotations

from functools import partial
from typing import Iterable, List, Optional, Set, Tuple

import numpy as np

from .findings import Finding

# Collectives whose axis names must be declared by the surrounding mesh.
COLLECTIVE_PRIMS = ("psum", "pmax", "pmin", "ppermute", "all_gather",
                    "all_to_all", "reduce_scatter", "axis_index", "pbroadcast")

FORBIDDEN_DTYPES = ("float64", "complex128")


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def _sub_jaxprs(params: dict):
    """Every Jaxpr/ClosedJaxpr nested in an eqn's params (pjit bodies,
    while cond/body, cond branches, scan, shard_map, custom_* calls)."""
    import jax.core as jcore
    out = []

    def visit(v):
        if isinstance(v, jcore.ClosedJaxpr):
            out.append(v.jaxpr)
        elif isinstance(v, jcore.Jaxpr):
            out.append(v)
        elif isinstance(v, (tuple, list)):
            for item in v:
                visit(item)

    for v in params.values():
        visit(v)
    return out


def iter_eqns(jaxpr):
    """Yield every eqn in ``jaxpr`` and (recursively) its sub-jaxprs."""
    import jax.core as jcore
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _eqn_axes(eqn) -> Tuple[str, ...]:
    """Named mesh axes an eqn's collective operates over."""
    axes = []
    for key in ("axes", "axis_name", "axis_index_groups_axis_name"):
        v = eqn.params.get(key)
        if v is None:
            continue
        for a in (v if isinstance(v, (tuple, list)) else (v,)):
            if isinstance(a, str):
                axes.append(a)
    return tuple(axes)


def collective_eqns(jaxpr):
    """[(prim_name, axes)] for every collective eqn in the graph."""
    return [(eqn.primitive.name, _eqn_axes(eqn))
            for eqn in iter_eqns(jaxpr)
            if eqn.primitive.name in COLLECTIVE_PRIMS]


def check_collective_axes(jaxpr, declared: Iterable[str],
                          program: str) -> List[Finding]:
    declared = set(declared)
    findings = []
    for prim, axes in collective_eqns(jaxpr):
        bad = [a for a in axes if a not in declared]
        if bad or not axes:
            findings.append(Finding(
                "C001", program, 0,
                f"collective `{prim}` names axis {bad or '<none>'} but the "
                f"mesh declares only {sorted(declared) or 'no axes'}"))
    return findings


def count_psums(jaxpr, axis: str) -> int:
    return sum(1 for prim, axes in collective_eqns(jaxpr)
               if prim == "psum" and axis in axes)


def check_while_carries(jaxpr, program: str) -> List[Finding]:
    """C002: while_loop carries fixed — body-out avals == carry-in avals,
    including weak_type (a weak carry re-traces or promotes downstream)."""
    findings = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "while":
            continue
        body = eqn.params["body_jaxpr"].jaxpr
        ncarry = len(body.outvars)
        carry_in = [v.aval for v in body.invars[-ncarry:]]
        carry_out = [v.aval for v in body.outvars]
        for i, (a_in, a_out) in enumerate(zip(carry_in, carry_out)):
            if a_in.shape != a_out.shape or a_in.dtype != a_out.dtype:
                findings.append(Finding(
                    "C002", program, 0,
                    f"while_loop carry[{i}] changes across the body: "
                    f"{a_in.str_short()} -> {a_out.str_short()}"))
            elif getattr(a_in, "weak_type", False) != \
                    getattr(a_out, "weak_type", False):
                findings.append(Finding(
                    "C002", program, 0,
                    f"while_loop carry[{i}] flips weak_type across the "
                    f"body ({a_in.str_short()} vs {a_out.str_short()}) — "
                    f"strengthen the init value (jnp.float32(...)/"
                    f"jnp.int32(...))"))
    return findings


def check_dtypes(jaxpr, program: str,
                 forbidden: Tuple[str, ...] = FORBIDDEN_DTYPES
                 ) -> List[Finding]:
    findings = []
    seen: Set[str] = set()
    for eqn in iter_eqns(jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in forbidden and (key := f"{eqn.primitive.name}:{dt}") \
                    not in seen:
                seen.add(key)
                findings.append(Finding(
                    "C003", program, 0,
                    f"{dt} aval flows through `{eqn.primitive.name}` — "
                    f"x64 creep; the engine is all-f32/int32 by contract"))
    return findings


# ---------------------------------------------------------------------------
# The real-program contract suite
# ---------------------------------------------------------------------------

def _tiny_problem(n: int = 6, m: int = 64, seed: int = 0):
    rng = np.random.default_rng(seed)
    arities = rng.integers(2, 4, size=n).astype(np.int32)
    data = (rng.integers(0, 10_000, size=(m, n)).astype(np.int32)
            % arities[None, :]).astype(np.int32)
    adj = np.zeros((n, n), dtype=np.int8)
    adj[0, 1] = adj[2, 1] = 1          # give delete sweeps real parents
    return data, arities, adj, int(arities.max())


def _structural_checks(jaxpr, program: str, declared=()) -> List[Finding]:
    return (check_collective_axes(jaxpr, declared, program)
            + check_while_carries(jaxpr, program)
            + check_dtypes(jaxpr, program))


def run_contract_checks(backends: Tuple[str, ...] = ("segment", "fused",
                                                     "fused_pallas"),
                        rounds: int = 3,
                        check_retrace: bool = True):
    """Trace the production programs and run every contract.

    Returns ``(findings, info)``; ``info`` records collective inventories,
    per-count-path psum counts and the retrace counters so the JSON report
    doubles as a contract snapshot.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from ..core import bdeu, fusion, score_cache
    from ..core.ges import GESConfig, ges_jit
    from ..core.ring import RingSpec, build_ring_program, ring_cges
    from ..core.sweeps import (shard_map_compat, sweep_column_body,
                               sweep_matrix_body,
                               sweep_matrix_restricted_body)
    from ..core.partition import pid_table_from_allowed

    findings: List[Finding] = []
    info: dict = {"programs": {}, "count_paths": {}, "retrace": {}}

    data_np, arities_np, adj_np, r_max = _tiny_problem()
    n, m = adj_np.shape[0], data_np.shape[0]
    ess, max_q = 10.0, 64
    data = jnp.asarray(data_np)
    arities = jnp.asarray(arities_np)
    adj = jnp.asarray(adj_np)

    def record(name, jaxpr, declared=()):
        findings.extend(_structural_checks(jaxpr, name, declared))
        inv = {}
        for prim, axes in collective_eqns(jaxpr):
            key = f"{prim}[{','.join(axes)}]"
            inv[key] = inv.get(key, 0) + 1
        info["programs"][name] = inv

    # ---- sweep matrices on every backend (no mesh: zero collectives) -----
    for impl in backends:
        for kind in ("insert", "delete"):
            fn = partial(sweep_matrix_body, ess=ess, max_q=max_q,
                         r_max=r_max, counts_impl=impl, kind=kind)
            record(f"sweep[{impl},{kind}]",
                   jax.make_jaxpr(fn)(data, arities, adj))

    # ---- C004: one psum per count path under a data mesh axis -------------
    axis = "data"
    mesh = Mesh(np.array(jax.devices()[:1]), (axis,))

    def psum_count_of(fn, *args):
        mapped = shard_map_compat(fn, mesh, (P(axis, None),), P())
        return jax.make_jaxpr(mapped)(*args), None

    count_paths = {}
    for impl in ("segment", "onehot", "pallas"):
        def single(d, impl=impl):
            pm = adj.astype(bool)[:, 1]
            return bdeu.local_score_masked(d, arities, 1, pm, ess, max_q,
                                           r_max, impl, data_axis_name=axis)
        jx, _ = psum_count_of(single, data)
        count_paths[f"local_score[{impl}]"] = count_psums(jx, axis)
        findings.extend(check_collective_axes(jx, {axis},
                                              f"local_score[{impl}]"))
    for impl in ("fused", "fused_pallas"):
        for kind, prim_fn in (("insert", bdeu.fused_insert_scores),
                              ("delete", bdeu.fused_delete_scores)):
            def fused(d, impl=impl, prim_fn=prim_fn):
                pm = adj.astype(bool)[:, 1]
                return prim_fn(d, arities, 1, pm, ess, max_q, r_max, impl,
                               data_axis_name=axis)
            jx, _ = psum_count_of(fused, data)
            count_paths[f"{kind}_scores[{impl}]"] = count_psums(jx, axis)
            findings.extend(check_collective_axes(
                jx, {axis}, f"{kind}_scores[{impl}]"))
    info["count_paths"] = count_paths
    for name, cnt in count_paths.items():
        if cnt != 1:
            findings.append(Finding(
                "C004", name, 0,
                f"count path contains {cnt} psums over the data axis — the "
                f"additive-table contract requires EXACTLY one (0 leaks "
                f"shard-local counts into the BDeu reduction, >1 double-"
                f"counts)"))

    # ---- ges_jit_body: full-n, restricted and cached variants -------------
    allowed = jnp.asarray(np.ones((n, n), dtype=np.int8)
                          - np.eye(n, dtype=np.int8))
    pid_table = jnp.asarray(
        pid_table_from_allowed(np.asarray(allowed, dtype=bool)))
    from ..core.ges import ges_jit_body
    lim = jnp.int32(4)
    for name, kwargs in (
            ("ges_jit_body", {}),
            ("ges_jit_body[restricted]", {"pid_table": pid_table}),
            ("ges_jit_body[cached]", {"cache": score_cache.init(n, n, 64)})):
        def prog(d, a, g, al, kw=kwargs):
            return ges_jit_body(d, a, g, al, lim, ess, 4, max_q, r_max,
                                "segment", 1e-9, True, **kw)
        record(name, jax.make_jaxpr(prog)(data, arities, adj, allowed))

    # ---- the restricted (W, n) ring program -------------------------------
    ndev = len(jax.devices())
    k = 2 if ndev >= 2 else 1
    d_ax = 2 if ndev >= 2 * k else 1
    ring_axes = ("ring",) if d_ax == 1 else ("ring", "data")
    devs = np.array(jax.devices()[:k * d_ax]).reshape(
        (k,) if d_ax == 1 else (k, d_ax))
    ring_mesh = Mesh(devs, ring_axes)
    spec = RingSpec(k=k, max_rounds=3,
                    data_axis=None if d_ax == 1 else "data",
                    data_axis_size=d_ax)
    config = GESConfig(ess=ess, max_q=max_q, counts_impl="segment")
    prog = build_ring_program(ring_mesh, spec, config, r_max, add_limit=4,
                              restricted=True)
    edge_masks = np.stack([np.asarray(allowed, dtype=np.int8)] * k)
    init_g = np.zeros((k, n, n), dtype=np.int8)
    pid_tables = np.stack([np.asarray(pid_table)] * k)
    ring_args = (data, arities, jnp.asarray(edge_masks),
                 jnp.asarray(init_g), jnp.asarray(pid_tables))
    record(f"ring[{'x'.join(map(str, devs.shape))}]",
           jax.make_jaxpr(prog)(*ring_args), declared=set(ring_axes))

    # ---- fuse_trace and the family-score cache ----------------------------
    g2 = jnp.asarray(np.triu(np.ones((n, n), dtype=np.int8), 1))
    record("fuse_trace", jax.make_jaxpr(fusion.fuse_trace)(adj, g2))

    def cache_prog(d):
        cache = score_cache.init(n, n, 64)
        pm = adj.astype(bool)[:, 1]

        def compute():
            return sweep_column_body(d, arities, adj, 1, None, ess, max_q,
                                     r_max, "segment", "insert")
        col, cache = score_cache.lookup_or_compute(
            cache, score_cache.KIND_INSERT, 1, pm, 0, compute)
        return col, cache.hits
    record("score_cache.lookup_or_compute", jax.make_jaxpr(cache_prog)(data))

    # ---- C005: zero steady-state re-traces --------------------------------
    if check_retrace:
        retrace = {}

        # the compiled ring: one program object, `rounds` same-shape calls
        jax.block_until_ready(prog(*ring_args))
        base = prog._cache_size()
        for r in range(rounds):
            jax.block_until_ready(prog(*ring_args))
        retrace["ring"] = prog._cache_size() - base

        # ges_jit steady state (module-level jitted impl — measure growth
        # after the warm-up call, not absolute size)
        from ..core.ges import _ges_jit_impl
        cfg = GESConfig(ess=ess, max_q=max_q, counts_impl="segment")
        ges_jit(data, arities, adj, allowed, add_limit=4, config=cfg,
                r_max=r_max, pid_table=pid_table)
        base = _ges_jit_impl._cache_size()
        for r in range(rounds):
            d_r, *_ = _tiny_problem(seed=r + 1)
            ges_jit(jnp.asarray(d_r), arities, adj, allowed, add_limit=4,
                    config=cfg, r_max=r_max, pid_table=pid_table)
        retrace["ges_jit"] = _ges_jit_impl._cache_size() - base

        # the jitted sweep entry (matrix path)
        from ..core.sweeps import _sweep_matrix
        from ..core.sweeps import sweep as sweep_api
        sweep_api(data, arities, adj, kind="insert", ess=ess, max_q=max_q,
                  r_max=r_max, counts_impl="segment")
        base = _sweep_matrix._cache_size()
        for r in range(rounds):
            d_r, *_ = _tiny_problem(seed=r + 11)
            sweep_api(jnp.asarray(d_r), arities, adj, kind="insert",
                      ess=ess, max_q=max_q, r_max=r_max,
                      counts_impl="segment")
        retrace["sweep"] = _sweep_matrix._cache_size() - base

        info["retrace"] = retrace
        for name, extra in retrace.items():
            if extra:
                findings.append(Finding(
                    "C005", name, 0,
                    f"{extra} re-trace(s) across {rounds} steady-state "
                    f"same-shape rounds — the compilation cache must not "
                    f"grow after warm-up (weak types / non-hashable "
                    f"statics / python-scalar leaks are the usual cause)"))

    return findings, info
