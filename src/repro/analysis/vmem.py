"""Pass 3 — symbolic VMEM budgets for the Pallas kernels.

A TPU core has ~16 MiB of VMEM.  Every Pallas kernel in this repo keeps an
accumulator (or running state) resident in VMEM across a sequential grid,
plus per-step input blocks and in-kernel one-hot/softmax temporaries — and
nothing checks that a (tile, max_q, r_pad) configuration actually fits
until the TPU compiler rejects it at paper scale (n = 1041 / max_q = 4096
is exactly where it gets tight).  This pass computes the footprint
symbolically from the same parameters the kernels take, so an over-budget
configuration fails at analysis time, with a per-term breakdown instead of
a compiler error.

Model (documented heuristic, deliberately conservative):

* input/output blocks whose BlockSpec index map depends on a grid axis are
  counted twice (Pallas pipelines them double-buffered); blocks with a
  constant index map (revisited accumulators) are counted once;
* ``scratch_shapes`` count once;
* named in-kernel temporaries (the one-hot slabs, the (BQ, BK) logits/probs
  pair, the scatter-by-matmul chunk) count once each — these are the terms
  that actually dominate (a (256, 4096) one-hot is 4 MiB).

The four kernels and their repo-default paper-scale configurations are
tabulated in ``DEFAULT_CONFIGS`` (tile defaults from the ops wrappers;
max_q / r_pad / k_pad at the GESConfig defaults and munin-scale n).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from .findings import Finding

MIB = 2 ** 20
# Per-core VMEM by platform.  v4/v5e/v5p are all ~16 MiB-class; "tpu" is
# the default gate.  A deliberately generous "interpret" budget exists so
# CPU-interpret runs (which have no real VMEM) can still exercise the gate.
VMEM_BUDGETS: Dict[str, int] = {
    "tpu": 16 * MIB,
    "tpu_v4": 16 * MIB,
    "tpu_v5e": 16 * MIB,
    "tpu_v5p": 16 * MIB,
}
DEFAULT_BUDGET = VMEM_BUDGETS["tpu"]

F32 = 4
I32 = 4


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class Term:
    name: str
    shape: Tuple[int, ...]
    elem_bytes: int = F32
    buffers: int = 1         # 2 = double-buffered streaming block

    @property
    def nbytes(self) -> int:
        return math.prod(self.shape) * self.elem_bytes * self.buffers


@dataclasses.dataclass
class Footprint:
    kernel: str
    params: Dict[str, int]
    terms: List[Term]

    @property
    def total_bytes(self) -> int:
        return sum(t.nbytes for t in self.terms)

    def check(self, budget: int = DEFAULT_BUDGET) -> Optional[Finding]:
        if self.total_bytes <= budget:
            return None
        top = sorted(self.terms, key=lambda t: -t.nbytes)[:3]
        detail = ", ".join(
            f"{t.name}{list(t.shape)}x{t.buffers}={t.nbytes / MIB:.1f}MiB"
            for t in top)
        return Finding(
            "V001", self.kernel, 0,
            f"VMEM footprint {self.total_bytes / MIB:.1f} MiB exceeds the "
            f"{budget / MIB:.0f} MiB budget with {self.params} — dominant "
            f"terms: {detail}; shrink the tile/chunk parameters")

    def to_dict(self) -> dict:
        return {
            "kernel": self.kernel,
            "params": self.params,
            "total_bytes": self.total_bytes,
            "total_mib": round(self.total_bytes / MIB, 3),
            "terms": {t.name: t.nbytes for t in self.terms},
        }


# ---------------------------------------------------------------------------
# Per-kernel symbolic footprints (mirror the kernels' BlockSpecs/scratch)
# ---------------------------------------------------------------------------

def bdeu_count_footprint(*, max_q: int = 4096, r_pad: int = 128,
                         tile_m: int = 256) -> Footprint:
    """kernels/bdeu_count: one-hot contraction, (max_q, r_pad) accumulator
    revisited across the sequential m grid."""
    return Footprint("bdeu_count", dict(max_q=max_q, r_pad=r_pad,
                                        tile_m=tile_m), [
        Term("in:cfg", (tile_m,), I32, buffers=2),
        Term("in:child", (tile_m,), I32, buffers=2),
        Term("out:counts", (max_q, r_pad), F32),          # constant index map
        Term("tmp:oh_cfg", (tile_m, max_q), F32),
        Term("tmp:oh_child", (tile_m, r_pad), F32),
    ])


def bdeu_sweep_footprint(*, max_q: int = 4096, r_max: int = 8,
                         tile_m: int = 256, tile_n: int = 32) -> Footprint:
    """kernels/bdeu_sweep.sweep_counts: joint child-value-batched insert
    sweep; the (max_q, tile_n * r_max) accumulator block rides the (b, c)
    grid axes (double-buffered), revisited across m innermost."""
    return Footprint("bdeu_sweep", dict(max_q=max_q, r_max=r_max,
                                        tile_m=tile_m, tile_n=tile_n), [
        Term("in:cfg", (tile_m,), I32, buffers=2),
        Term("in:child", (tile_m,), I32, buffers=2),
        Term("in:data", (tile_m, tile_n), I32, buffers=2),
        Term("out:counts", (max_q, tile_n * r_max), F32, buffers=2),
        Term("tmp:oh_cfg", (tile_m, max_q), F32),
        Term("tmp:oh_all", (tile_m, tile_n * r_max), F32),
    ])


def bdeu_delete_footprint(*, max_q: int = 4096, r_pad: int = 128,
                          tile_m: int = 256, k_pad: int = 1152,
                          n_slots: int = 11,
                          chunk_q: Optional[int] = None) -> Footprint:
    """kernels/bdeu_sweep.delete_scores: VMEM-resident family table +
    in-VMEM scatter-by-matmul marginalization (PR 5).  ``chunk_q`` defaults
    to the kernel's own min(max_q, 256) bound; k_pad = round_up(n | W, 128);
    n_slots <= floor(log2(max_q))."""
    cq = min(max_q, 256) if chunk_q is None else chunk_q
    return Footprint("bdeu_delete", dict(max_q=max_q, r_pad=r_pad,
                                         tile_m=tile_m, k_pad=k_pad,
                                         n_slots=n_slots, chunk_q=cq), [
        Term("in:cfg", (tile_m,), I32, buffers=2),
        Term("in:child", (tile_m,), I32, buffers=2),
        Term("in:cand+slots", (k_pad + 3 * n_slots + 2,), I32),
        Term("out:scores", (k_pad,), F32),
        Term("scratch:family_table", (max_q, r_pad), F32),
        Term("tmp:oh_cfg", (tile_m, max_q), F32),
        Term("tmp:oh_child", (tile_m, r_pad), F32),
        Term("tmp:scatter_onehot", (cq, max_q), F32),
        Term("tmp:marginal_acc", (max_q, r_pad), F32),
        Term("tmp:chunk_rows", (cq, r_pad), F32),
    ])


def flash_attention_footprint(*, block_q: int = 128, block_k: int = 128,
                              head_dim: int = 128) -> Footprint:
    """kernels/flash_attention: online-softmax attention; q/out blocks ride
    the query grid, k/v the (sequential) KV grid, stats persist in scratch."""
    return Footprint("flash_attention", dict(block_q=block_q,
                                             block_k=block_k,
                                             head_dim=head_dim), [
        Term("in:q", (block_q, head_dim), F32, buffers=2),
        Term("in:k", (block_k, head_dim), F32, buffers=2),
        Term("in:v", (block_k, head_dim), F32, buffers=2),
        Term("out:o", (block_q, head_dim), F32, buffers=2),
        Term("scratch:acc", (block_q, head_dim), F32),
        Term("scratch:m", (block_q, 128), F32),
        Term("scratch:l", (block_q, 128), F32),
        Term("tmp:logits", (block_q, block_k), F32),
        Term("tmp:probs", (block_q, block_k), F32),
    ])


def ssd_scan_footprint(*, chunk: int = 128, head_dim_p: int = 64,
                       state_n: int = 128) -> Footprint:
    """kernels/ssd_scan: Mamba2 chunked scan; (N, P) state in scratch,
    chunk-local quadratic decay mask as the dominant temporary."""
    return Footprint("ssd_scan", dict(chunk=chunk, head_dim_p=head_dim_p,
                                      state_n=state_n), [
        Term("in:x", (chunk, head_dim_p), F32, buffers=2),
        Term("in:a", (chunk,), F32, buffers=2),
        Term("in:b", (chunk, state_n), F32, buffers=2),
        Term("in:c", (chunk, state_n), F32, buffers=2),
        Term("out:y", (chunk, head_dim_p), F32, buffers=2),
        Term("scratch:state", (state_n, head_dim_p), F32),
        Term("tmp:decay_mask", (chunk, chunk), F32),
        Term("tmp:cb", (chunk, chunk), F32),
        Term("tmp:y_intra+inter", (2 * chunk, head_dim_p), F32),
        Term("tmp:w", (chunk, state_n), F32),
    ])


KERNEL_FOOTPRINTS: Dict[str, Callable[..., Footprint]] = {
    "bdeu_count": bdeu_count_footprint,
    "bdeu_sweep": bdeu_sweep_footprint,
    "bdeu_delete": bdeu_delete_footprint,
    "flash_attention": flash_attention_footprint,
    "ssd_scan": ssd_scan_footprint,
}

# Paper-scale representative configurations: GESConfig.max_q = 4096, the
# compiled r_pad = round_up(r_max, 128) = 128, munin-scale candidate column
# k_pad = round_up(1041, 128) = 1152, tiles at the ops-wrapper defaults.
DEFAULT_CONFIGS: Dict[str, Dict[str, int]] = {
    "bdeu_count": dict(max_q=4096, r_pad=128, tile_m=256),
    "bdeu_sweep": dict(max_q=4096, r_max=8, tile_m=256, tile_n=32),
    "bdeu_delete": dict(max_q=4096, r_pad=128, tile_m=256,
                        k_pad=_round_up(1041, 128), n_slots=11),
    "flash_attention": dict(block_q=128, block_k=128, head_dim=128),
    "ssd_scan": dict(chunk=128, head_dim_p=64, state_n=128),
}


def footprint(kernel: str, **params) -> Footprint:
    if kernel not in KERNEL_FOOTPRINTS:
        raise ValueError(f"unknown kernel {kernel!r}; valid: "
                         f"{sorted(KERNEL_FOOTPRINTS)}")
    return KERNEL_FOOTPRINTS[kernel](**params)


def check_config(kernel: str, budget: int = DEFAULT_BUDGET,
                 **params) -> Optional[Finding]:
    """Budget-gate one kernel configuration; None when it fits."""
    return footprint(kernel, **params).check(budget)


def run_vmem_checks(budget: int = DEFAULT_BUDGET,
                    configs: Optional[Dict[str, Dict[str, int]]] = None):
    """Footprint every kernel at its (default or given) configuration.

    Returns ``(findings, info)`` — info carries the full per-term breakdown
    for the JSON report (the budget table in the package docstring is
    generated from exactly this)."""
    configs = DEFAULT_CONFIGS if configs is None else configs
    findings: List[Finding] = []
    info = {"budget_bytes": budget, "kernels": {}}
    for kernel, params in configs.items():
        fp = footprint(kernel, **params)
        info["kernels"][kernel] = fp.to_dict()
        bad = fp.check(budget)
        if bad is not None:
            findings.append(bad)
    return findings, info
