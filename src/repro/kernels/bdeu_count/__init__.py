from .ops import contingency_counts
from .ref import contingency_counts_ref
from .bdeu_count import contingency_counts_pallas
