"""Jit'd public wrapper for the bdeu_count Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .bdeu_count import contingency_counts_pallas
from .ref import contingency_counts_ref


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _resolve_interpret(interpret) -> bool:
    """``interpret=None`` (the default) resolves per-backend at trace time:
    interpret mode everywhere except an actual TPU, where the validated
    kernel compiles.  An explicit bool wins."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


@partial(jax.jit, static_argnames=("max_q", "r_max", "tile_m", "interpret",
                                   "use_ref", "data_axis_name"))
def contingency_counts(
    cfg: jax.Array,
    child: jax.Array,
    *,
    max_q: int,
    r_max: int,
    tile_m: int = 256,
    interpret: bool | None = None,
    use_ref: bool = False,
    data_axis_name: str | None = None,
) -> jax.Array:
    """(max_q, r_max) f32 contingency table for one (parent-config, child) pair.

    Pads m to a tile multiple (sentinel cfg = max_q counts nothing) and the
    child axis to the 128-lane MXU boundary; the validated Pallas kernel runs
    in interpret mode on CPU and compiled on TPU (``interpret=None`` resolves
    per-backend).

    ``data_axis_name``: inside shard_map with the instance axis sharded, each
    device counts only its m/d shard; contingency tables are additive over
    instances, so one ``psum`` over that mesh axis reconstructs the global
    table before the (m-independent) BDeu reduction.
    """
    interpret = _resolve_interpret(interpret)
    m = cfg.shape[0]
    m_pad = _round_up(max(m, tile_m), tile_m)
    r_pad = _round_up(r_max, 128)
    cfg_p = jnp.full((m_pad,), max_q, dtype=jnp.int32).at[:m].set(
        cfg.astype(jnp.int32))
    child_p = jnp.zeros((m_pad,), dtype=jnp.int32).at[:m].set(
        child.astype(jnp.int32))
    if use_ref:
        counts = contingency_counts_ref(cfg_p, child_p, max_q=max_q, r_pad=r_pad)
    else:
        counts = contingency_counts_pallas(
            cfg_p, child_p, max_q=max_q, r_pad=r_pad, tile_m=tile_m,
            interpret=interpret)
    counts = counts[:, :r_max]
    if data_axis_name is not None:
        counts = jax.lax.psum(counts, data_axis_name)
    return counts
