"""Pallas TPU kernel: contingency-table accumulation for BDeu scoring.

The GPU-idiomatic implementation of N_ijk counting is an atomic scatter-add
over a hash of the parent configuration.  TPUs have no fast scatter; the
TPU-native formulation is a *one-hot contraction on the MXU*:

    counts[q, r] = sum_t  onehot(cfg[t])[q] * onehot(child[t])[r]
                 = OH_cfg^T @ OH_child          # (max_q, TILE_M)@(TILE_M, r)

tiled over the instance axis so each (TILE_M, max_q) one-hot slab lives in
VMEM only transiently, while the (max_q, r_pad) accumulator stays resident in
VMEM across the sequential grid.  Counts are exact in f32 (m << 2^24).

Grid:      (m // TILE_M,)  — sequential on TPU, accumulator revisited.
BlockSpec: cfg/child tiles (TILE_M,); output block (max_q, r_pad) pinned.
Padding:   out-of-range cfg values (>= max_q, e.g. the m-padding sentinel)
           produce all-zero one-hot rows and therefore count nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cfg_ref, child_ref, out_ref, *, max_q: int, r_pad: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    cfg = cfg_ref[...]          # (TILE_M,) int32
    child = child_ref[...]      # (TILE_M,) int32
    tile_m = cfg.shape[0]

    q_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_m, max_q), 1)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_m, r_pad), 1)
    oh_cfg = (cfg[:, None] == q_iota).astype(jnp.float32)      # (TILE_M, max_q)
    oh_child = (child[:, None] == r_iota).astype(jnp.float32)  # (TILE_M, r_pad)

    out_ref[...] += jax.lax.dot_general(
        oh_cfg, oh_child,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def contingency_counts_pallas(
    cfg: jax.Array,
    child: jax.Array,
    *,
    max_q: int,
    r_pad: int,
    tile_m: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """(max_q, r_pad) f32 counts. cfg/child: (m,) int32, m % tile_m == 0."""
    m = cfg.shape[0]
    if m % tile_m != 0:
        raise ValueError(
            f"contingency_counts_pallas: m={m} must be a multiple of "
            f"tile_m={tile_m} (ops.contingency_counts pads)")
    grid = (m // tile_m,)
    return pl.pallas_call(
        functools.partial(_kernel, max_q=max_q, r_pad=r_pad),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m,), lambda i: (i,)),
            pl.BlockSpec((tile_m,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((max_q, r_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((max_q, r_pad), jnp.float32),
        interpret=interpret,
    )(cfg, child)
