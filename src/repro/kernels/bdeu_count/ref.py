"""Pure-jnp oracle for the bdeu_count kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def contingency_counts_ref(
    cfg: jax.Array, child: jax.Array, *, max_q: int, r_pad: int
) -> jax.Array:
    """Dense (max_q, r_pad) contingency counts; out-of-range cfg rows ignored."""
    valid = (cfg >= 0) & (cfg < max_q)
    flat = jnp.where(valid, cfg, 0) * r_pad + jnp.clip(child, 0, r_pad - 1)
    counts = jax.ops.segment_sum(
        valid.astype(jnp.float32), flat, num_segments=max_q * r_pad
    )
    return counts.reshape(max_q, r_pad)
