"""Jit'd public wrappers for the bdeu_sweep Pallas kernels."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .bdeu_sweep import delete_scores_pallas, sweep_counts_pallas
from .ref import delete_scores_ref, sweep_counts_ref


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _resolve_interpret(interpret) -> bool:
    """``interpret=None`` (the default) resolves per-backend at trace time:
    interpret mode everywhere except an actual TPU, where the validated
    kernel compiles — so 'interpret on CPU, compiled on TPU' is the
    behavior, not just the docstring.  An explicit bool wins."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


@partial(jax.jit, static_argnames=("max_q", "r_max", "tile_m", "tile_n",
                                   "interpret", "use_ref", "data_axis_name"))
def sweep_counts(
    cfg: jax.Array,
    child: jax.Array,
    data: jax.Array,
    *,
    max_q: int,
    r_max: int,
    tile_m: int = 256,
    tile_n: int = 32,
    interpret: bool | None = None,
    use_ref: bool = False,
    data_axis_name: str | None = None,
) -> jax.Array:
    """(r_max, max_q, n*r_max) f32 joint sweep counts for one child.

    counts[b, j0, x*r_max + a] = #(child=b, base-config=j0, X_x=a) — every
    candidate family's contingency table for the FES sweep in one call.
    Pads m and n to tile multiples with counting-neutral sentinels (cfg=max_q,
    child/data=r_max: all-zero one-hot rows/columns) and slices the padding
    back off; the validated Pallas kernel runs in interpret mode on CPU and
    compiled on TPU (``interpret=None`` resolves per-backend).

    ``data_axis_name``: inside shard_map with the instance axis sharded, each
    device contracts only its m/d one-hot shard; the joint counts are
    additive over instances, so one ``psum`` over that mesh axis rebuilds the
    global tables before the (m-independent) BDeu reduction.
    """
    interpret = _resolve_interpret(interpret)
    m, n = data.shape
    m_pad = _round_up(max(m, tile_m), tile_m)
    n_pad = _round_up(max(n, tile_n), tile_n)
    cfg_p = jnp.full((m_pad,), max_q, dtype=jnp.int32).at[:m].set(
        cfg.astype(jnp.int32))
    child_p = jnp.full((m_pad,), r_max, dtype=jnp.int32).at[:m].set(
        child.astype(jnp.int32))
    data_p = jnp.full((m_pad, n_pad), r_max, dtype=jnp.int32).at[:m, :n].set(
        data.astype(jnp.int32))
    if use_ref:
        counts = sweep_counts_ref(cfg_p, child_p, data_p,
                                  max_q=max_q, r_max=r_max)
    else:
        counts = sweep_counts_pallas(cfg_p, child_p, data_p,
                                     max_q=max_q, r_max=r_max,
                                     tile_m=tile_m, tile_n=tile_n,
                                     interpret=interpret)
    counts = counts[:, :, :n * r_max]
    if data_axis_name is not None:
        counts = jax.lax.psum(counts, data_axis_name)
    return counts


@partial(jax.jit, static_argnames=("max_q", "r_max", "tile_m", "tile_n",
                                   "interpret", "use_ref", "data_axis_name"))
def sweep_counts_restricted(
    cfg: jax.Array,
    child: jax.Array,
    data: jax.Array,
    pids: jax.Array,
    *,
    max_q: int,
    r_max: int,
    tile_m: int = 256,
    tile_n: int = 32,
    interpret: bool | None = None,
    use_ref: bool = False,
    data_axis_name: str | None = None,
) -> jax.Array:
    """(r_max, max_q, W*r_max) joint sweep counts over the W candidates in
    ``pids`` only — the restricted-E_i variant for the ring.

    The candidate data columns are gathered BEFORE the one-hot contraction,
    so the kernel's candidate axis (grid width, accumulator block and flops)
    is W, not n: a ring process with |E_i| ~ n/k allowed parents per column
    pays a W-wide contraction, tracking the partition exactly like the loop
    engine's W per-candidate table builds.  The column tile is shrunk to the
    (padded) W so a narrow restriction does not pay a full default tile.

    This is the contraction behind BOTH restricted paths: the host-engine
    driver's per-column ``pids`` sweeps and the compiled ges_jit/shard_map
    ring, whose (n, W) pid_table matrix sweeps call it once per child from
    inside the while_loop (core/sweeps.sweep_matrix_restricted_body).
    """
    data_w = jnp.take(data, pids, axis=1)
    w = data_w.shape[1]
    tn = min(tile_n, _round_up(w, 8))
    return sweep_counts(cfg, child, data_w, max_q=max_q, r_max=r_max,
                        tile_m=tile_m, tile_n=tn, interpret=interpret,
                        use_ref=use_ref, data_axis_name=data_axis_name)


@partial(jax.jit, static_argnames=("ess", "max_q", "r_max", "tile_m",
                                   "interpret", "use_ref"))
def delete_scores(
    cfg: jax.Array,
    child: jax.Array,
    cand_slot: jax.Array,
    slot_ar: jax.Array,
    slot_low: jax.Array,
    qr: jax.Array,
    *,
    ess: float,
    max_q: int,
    r_max: int,
    tile_m: int = 256,
    interpret: bool | None = None,
    use_ref: bool = False,
) -> jax.Array:
    """(K,) BDeu scores of ALL delete candidates of one child — the
    VMEM-resident BES column.

    cfg/child: (m,) int32 current-family radix codes and child values.
    cand_slot: (K,) int32 mapping each candidate to its marginalization slot
    (0 = not a parent -> base-family score), slot_ar/slot_low: (S,) int32
    per-slot arity/place value (identity 1/1 on padding slots), qr:
    (S + 2,) f32 = [q0, q_del per slot..., r_child].  The ONE family table is
    built in VMEM and each slot marginal is reduced to its score without the
    (max_q, r) slab ever reaching HBM; only this (K,) column is written.

    Pads m to a tile multiple (sentinel cfg = max_q counts nothing) and the
    candidate axis to the 128-lane boundary (slot 0, sliced back off).  The
    child axis of the VMEM table is padded to the f32 sublane boundary in
    interpret mode and the full 128-lane boundary compiled — zero-count
    padding columns contribute exactly 0 either way.  The validated Pallas
    kernel runs in interpret mode on CPU and compiled on TPU
    (``interpret=None`` resolves per-backend); the max_q overflow guard
    stays in ``bdeu.fused_delete_scores`` (shared with the jnp reference
    path).

    NOTE: this kernel reduces counts to SCORES in-VMEM, and scores (unlike
    counts) are not additive over instance shards — so it deliberately takes
    no ``data_axis_name``.  Under data sharding ``bdeu.fused_delete_scores``
    routes to the two-step table-build + marginalization path (whose counts
    CAN be psum'd); this kernel's per-shard accumulation is unchanged.
    """
    interpret = _resolve_interpret(interpret)
    m = cfg.shape[0]
    k = cand_slot.shape[0]
    m_pad = _round_up(max(m, tile_m), tile_m)
    k_pad = _round_up(max(k, 1), 128)
    r_pad = _round_up(r_max, 8 if interpret else 128)
    # Sentinel DATA rows (core/sweeps.pad_data_rows writes r_max into every
    # column, so child == r_max there) get the same cfg = max_q drop the
    # m-padding below uses: the VMEM table's child axis is r_pad >= r_max
    # wide, so an unmasked sentinel row would land in a padding column with
    # an in-range cfg instead of vanishing.
    cfg = jnp.where(child.astype(jnp.int32) < r_max,
                    cfg.astype(jnp.int32), max_q)
    cfg_p = jnp.full((m_pad,), max_q, dtype=jnp.int32).at[:m].set(
        cfg.astype(jnp.int32))
    child_p = jnp.zeros((m_pad,), dtype=jnp.int32).at[:m].set(
        child.astype(jnp.int32))
    cand_p = jnp.zeros((k_pad,), dtype=jnp.int32).at[:k].set(
        cand_slot.astype(jnp.int32))
    if use_ref:
        scores = delete_scores_ref(cfg_p, child_p, cand_p,
                                   slot_ar.astype(jnp.int32),
                                   slot_low.astype(jnp.int32),
                                   qr.astype(jnp.float32),
                                   max_q=max_q, r_pad=r_pad, ess=ess)
    else:
        scores = delete_scores_pallas(cfg_p, child_p, cand_p,
                                      slot_ar.astype(jnp.int32),
                                      slot_low.astype(jnp.int32),
                                      qr.astype(jnp.float32),
                                      max_q=max_q, r_pad=r_pad, ess=ess,
                                      tile_m=tile_m, interpret=interpret)
    return scores[:k]
