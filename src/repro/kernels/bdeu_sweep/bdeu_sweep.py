"""Pallas TPU kernels: fused all-candidate sweeps for BDeu deltas.

Two kernels, one per GES phase:

**Insert (FES)** — ``sweep_counts_pallas``.  The candidate sweep for one
child evaluates all n families (Pa + {x}) at once.  The per-candidate loop
engine issues n independent ``bdeu_count`` contractions — each a memory-bound
(max_q, m) @ (m, r_max) matmul using r_max/128 of the MXU lanes.  The
extended parent configuration factorizes, ``cfg_x = (cfg0, X_x)``, so the
whole sweep is ONE joint contraction batched over the child's value b:

    counts[b, j0, x*r_max + a] = sum_t [child[t]=b][cfg0[t]=j0][data[t,x]=a]
                               = OH(cfg0 | child=b)^T @ OH_all(data)

i.e. r_max (max_q, m) @ (m, n*r_max) matmuls — full lane utilization, and
n / r_max fewer dispatches per child than the loop engine.

Grid:      (r_max, n_tiles, m_tiles) — m innermost, sequential on TPU, so the
           (max_q, TILE_N * r_max) accumulator block stays resident in VMEM
           across the m sweep and is revisited, exactly like ``bdeu_count``.
BlockSpec: cfg/child tiles (TILE_M,); data tile (TILE_M, TILE_N) int32 —
           one-hots are built in-kernel from iota compares, so HBM traffic is
           the int32 data, not the r_max-times-larger one-hot.
Padding:   out-of-range cfg (>= max_q) or child (>= r_max, the m-padding
           sentinel) rows produce all-zero one-hot rows and count nothing;
           padded data columns hold the sentinel r_max and yield all-zero
           count columns.  Zero-count cells cancel exactly in the BDeu sum
           (lgamma(N + a) - lgamma(a) = 0 at N = 0), so padding is exact.
Counting is exact in f32 for m << 2^24, same argument as ``bdeu_count``.

**Delete (BES)** — ``delete_scores_pallas``.  Every candidate table
``counts(Pa - {x})`` is a *marginalization* of the ONE current-family
(max_q, r) table over parent slot x (see ``bdeu.fused_delete_scores`` for the
radix-code algebra).  The two-step fused path builds that table with
``bdeu_count`` and hands the slab back to jnp, round-tripping it through HBM
once per column.  This kernel keeps it VMEM-resident end-to-end: the table is
accumulated into a VMEM scratch across the m grid, and on the final grid step
each of the <= n_slots parent-slot marginals is formed *in VMEM* and reduced
straight to its BDeu score — only the (K,) per-candidate score column is ever
written back.

Grid:      (m_tiles,) — sequential on TPU; the (max_q, r_pad) scratch
           accumulator is revisited, exactly like ``bdeu_count``.
Marginalization:  TPU has no fast gather/scatter, so the digit-sum
           M[j'] = sum_{t(j0) = j'} counts[j0] with
           t(j0) = (j0 // (low*ar)) * low + (j0 % low)
           is a scatter-by-matmul: the (chunk_q, max_q) one-hot of t built
           from iota compares, contracted against the matching scratch rows
           (chunked so the one-hot never exceeds a VMEM-friendly block).
           Identity slots (ar = 1, low = 1) give t = j0 — the base family —
           so padded slots are exact no-ops, and slot 0 is the base score.
Output:    out[c] = slot_scores[cand_slot[c]] via a one-hot gather: slot 0
           (base) for candidates not in Pa (the jnp reference's no-op
           convention), slot s+1 for the candidate deleting parent slot s.
The max_q overflow guard (+/-inf) stays in ``bdeu.fused_delete_scores`` —
identical conventions for the kernel and the jnp reference by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import bdeu_table_score


def _kernel(cfg_ref, child_ref, data_ref, out_ref, *, max_q: int, r_max: int):
    b = pl.program_id(0)
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    cfg = cfg_ref[...]          # (TILE_M,) int32, sentinel max_q on padding
    child = child_ref[...]      # (TILE_M,) int32, sentinel r_max on padding
    data = data_ref[...]        # (TILE_M, TILE_N) int32, sentinel r_max cols
    tile_m = cfg.shape[0]
    tile_n = data.shape[1]

    # select instances with child value b; others become all-zero one-hot rows
    sel = jnp.where(child == b, cfg, max_q)
    q_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_m, max_q), 1)
    oh_cfg = (sel[:, None] == q_iota).astype(jnp.float32)   # (TILE_M, max_q)

    a_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_m, tile_n, r_max), 2)
    oh_all = (data[:, :, None] == a_iota).astype(jnp.float32)
    oh_all = oh_all.reshape(tile_m, tile_n * r_max)         # (TILE_M, TILE_N*r)

    out_ref[...] += jax.lax.dot_general(
        oh_cfg, oh_all,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[None]


def sweep_counts_pallas(
    cfg: jax.Array,
    child: jax.Array,
    data: jax.Array,
    *,
    max_q: int,
    r_max: int,
    tile_m: int = 256,
    tile_n: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """(r_max, max_q, n*r_max) f32 joint sweep counts.

    cfg/child: (m,) int32; data: (m, n) int32.  m % tile_m == 0 and
    n % tile_n == 0 (callers pad; see ops.sweep_counts).
    """
    m, n = data.shape
    if m % tile_m != 0:
        raise ValueError(
            f"sweep_counts_pallas: m={m} must be a multiple of "
            f"tile_m={tile_m} (ops.sweep_counts pads)")
    if n % tile_n != 0:
        raise ValueError(
            f"sweep_counts_pallas: n={n} must be a multiple of "
            f"tile_n={tile_n} (ops.sweep_counts pads)")
    grid = (r_max, n // tile_n, m // tile_m)
    return pl.pallas_call(
        functools.partial(_kernel, max_q=max_q, r_max=r_max),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m,), lambda b, c, i: (i,)),
            pl.BlockSpec((tile_m,), lambda b, c, i: (i,)),
            pl.BlockSpec((tile_m, tile_n), lambda b, c, i: (i, c)),
        ],
        out_specs=pl.BlockSpec((1, max_q, tile_n * r_max),
                               lambda b, c, i: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((r_max, max_q, n * r_max), jnp.float32),
        interpret=interpret,
    )(cfg, child, data)


def _delete_kernel(cfg_ref, child_ref, cand_ref, ar_ref, low_ref, qr_ref,
                   out_ref, counts_ref, *, max_q: int, r_pad: int,
                   n_slots: int, ess: float, chunk_q: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _zero():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    # ---- phase 1: accumulate the current-family table into VMEM scratch ----
    cfg = cfg_ref[...]          # (TILE_M,) int32, sentinel max_q on padding
    child = child_ref[...]      # (TILE_M,) int32
    tile_m = cfg.shape[0]
    q_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_m, max_q), 1)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_m, r_pad), 1)
    oh_cfg = (cfg[:, None] == q_iota).astype(jnp.float32)
    oh_child = (child[:, None] == r_iota).astype(jnp.float32)
    counts_ref[...] += jax.lax.dot_general(
        oh_cfg, oh_child,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # ---- phase 2 (final step): marginalize + reduce, all in VMEM ----------
    @pl.when(step == pl.num_programs(0) - 1)
    def _reduce():
        qr = qr_ref[...]                   # [q0, q_del_0..q_del_{S-1}, r]
        r = qr[n_slots + 1]

        def bdeu(tbl, q):
            # THE shared reduction (plain jnp, traces in-kernel): zero-count
            # rows/cells (incl. the r_pad padding columns) contribute 0
            return bdeu_table_score(tbl, q, r, ess)

        slot_scores = [bdeu(counts_ref[...], qr[0])]     # slot 0: base family
        ar_v = ar_ref[...]
        low_v = low_ref[...]
        for s in range(n_slots):
            ar = ar_v[s]
            low = low_v[s]

            def chunk_body(c, M):
                # rows j0 in [c*chunk_q, (c+1)*chunk_q) scatter to t(j0);
                # one-hot-matmul instead of scatter (TPU-native).  When
                # chunk_q does not divide max_q the last chunk is shifted
                # back to stay in bounds and its already-processed overlap
                # rows are masked to the sel-row-zero sentinel.
                start = jnp.minimum(c * chunk_q, max_q - chunk_q)
                j0 = (jax.lax.broadcasted_iota(
                    jnp.int32, (chunk_q, max_q), 0) + start)
                t = (j0 // (low * ar)) * low + (j0 % low)
                t = jnp.where(j0 >= c * chunk_q, t, max_q)
                jp = jax.lax.broadcasted_iota(jnp.int32, (chunk_q, max_q), 1)
                sel = (t == jp).astype(jnp.float32)      # (chunk_q, max_q)
                rows = pl.load(counts_ref,
                               (pl.ds(start, chunk_q), slice(None)))
                return M + jax.lax.dot_general(
                    sel, rows,
                    dimension_numbers=(((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)

            n_chunks = -(-max_q // chunk_q)
            M = jax.lax.fori_loop(0, n_chunks, chunk_body,
                                  jnp.zeros((max_q, r_pad), jnp.float32))
            slot_scores.append(bdeu(M, qr[1 + s]))

        sv = jnp.stack(slot_scores)                      # (n_slots + 1,)
        cand = cand_ref[...]                             # (K_pad,) slot ids
        k_pad = cand.shape[0]
        s_iota = jax.lax.broadcasted_iota(jnp.int32, (k_pad, n_slots + 1), 1)
        oh = (cand[:, None] == s_iota).astype(jnp.float32)
        out_ref[...] = jnp.sum(oh * sv[None, :], axis=1)


def delete_scores_pallas(
    cfg: jax.Array,
    child: jax.Array,
    cand_slot: jax.Array,
    slot_ar: jax.Array,
    slot_low: jax.Array,
    qr: jax.Array,
    *,
    max_q: int,
    r_pad: int,
    ess: float,
    tile_m: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """(K,) BDeu scores of the delete-candidate families, VMEM-resident.

    cfg/child: (m,) int32, m % tile_m == 0 (cfg sentinel max_q on padding).
    cand_slot: (K,) int32 — 0 for candidates not in Pa (score = base family),
    s+1 for the candidate that deletes parent slot s.  slot_ar/slot_low:
    (n_slots,) int32 per-slot arity and radix place value (1/1 = identity
    padding).  qr: (n_slots + 2,) f32 = [q0, q_del per slot..., r_child].
    K and n_slots are static via the argument shapes (callers pad; see
    ops.delete_scores).
    """
    m = cfg.shape[0]
    if m % tile_m != 0:
        raise ValueError(
            f"delete_scores_pallas: m={m} must be a multiple of "
            f"tile_m={tile_m} (ops.delete_scores pads)")
    n_slots = slot_ar.shape[0]
    k_pad = cand_slot.shape[0]
    # One-hot chunk bound: the (chunk_q, max_q) scatter matrix stays <= ~4 MB
    # of VMEM at max_q = 4096 regardless of divisibility (a non-multiple
    # max_q gets a shifted, overlap-masked final chunk — see _delete_kernel).
    chunk_q = min(max_q, 256)
    grid = (m // tile_m,)
    return pl.pallas_call(
        functools.partial(_delete_kernel, max_q=max_q, r_pad=r_pad,
                          n_slots=n_slots, ess=ess, chunk_q=chunk_q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m,), lambda i: (i,)),
            pl.BlockSpec((tile_m,), lambda i: (i,)),
            pl.BlockSpec((k_pad,), lambda i: (0,)),
            pl.BlockSpec((n_slots,), lambda i: (0,)),
            pl.BlockSpec((n_slots,), lambda i: (0,)),
            pl.BlockSpec((n_slots + 2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((k_pad,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((k_pad,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((max_q, r_pad), jnp.float32)],
        interpret=interpret,
    )(cfg, child, cand_slot, slot_ar, slot_low, qr)
