"""Pallas TPU kernel: fused all-candidate contingency sweep for BDeu deltas.

The FES candidate sweep for one child evaluates all n families (Pa + {x}) at
once.  The per-candidate loop engine issues n independent ``bdeu_count``
contractions — each a memory-bound (max_q, m) @ (m, r_max) matmul using
r_max/128 of the MXU lanes.  The extended parent configuration factorizes,
``cfg_x = (cfg0, X_x)``, so the whole sweep is ONE joint contraction batched
over the child's value b:

    counts[b, j0, x*r_max + a] = sum_t [child[t]=b][cfg0[t]=j0][data[t,x]=a]
                               = OH(cfg0 | child=b)^T @ OH_all(data)

i.e. r_max (max_q, m) @ (m, n*r_max) matmuls — full lane utilization, and
n / r_max fewer dispatches per child than the loop engine.

Grid:      (r_max, n_tiles, m_tiles) — m innermost, sequential on TPU, so the
           (max_q, TILE_N * r_max) accumulator block stays resident in VMEM
           across the m sweep and is revisited, exactly like ``bdeu_count``.
BlockSpec: cfg/child tiles (TILE_M,); data tile (TILE_M, TILE_N) int32 —
           one-hots are built in-kernel from iota compares, so HBM traffic is
           the int32 data, not the r_max-times-larger one-hot.
Padding:   out-of-range cfg (>= max_q) or child (>= r_max, the m-padding
           sentinel) rows produce all-zero one-hot rows and count nothing;
           padded data columns hold the sentinel r_max and yield all-zero
           count columns.  Zero-count cells cancel exactly in the BDeu sum
           (lgamma(N + a) - lgamma(a) = 0 at N = 0), so padding is exact.
Counting is exact in f32 for m << 2^24, same argument as ``bdeu_count``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cfg_ref, child_ref, data_ref, out_ref, *, max_q: int, r_max: int):
    b = pl.program_id(0)
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    cfg = cfg_ref[...]          # (TILE_M,) int32, sentinel max_q on padding
    child = child_ref[...]      # (TILE_M,) int32, sentinel r_max on padding
    data = data_ref[...]        # (TILE_M, TILE_N) int32, sentinel r_max cols
    tile_m = cfg.shape[0]
    tile_n = data.shape[1]

    # select instances with child value b; others become all-zero one-hot rows
    sel = jnp.where(child == b, cfg, max_q)
    q_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_m, max_q), 1)
    oh_cfg = (sel[:, None] == q_iota).astype(jnp.float32)   # (TILE_M, max_q)

    a_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_m, tile_n, r_max), 2)
    oh_all = (data[:, :, None] == a_iota).astype(jnp.float32)
    oh_all = oh_all.reshape(tile_m, tile_n * r_max)         # (TILE_M, TILE_N*r)

    out_ref[...] += jax.lax.dot_general(
        oh_cfg, oh_all,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[None]


def sweep_counts_pallas(
    cfg: jax.Array,
    child: jax.Array,
    data: jax.Array,
    *,
    max_q: int,
    r_max: int,
    tile_m: int = 256,
    tile_n: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """(r_max, max_q, n*r_max) f32 joint sweep counts.

    cfg/child: (m,) int32; data: (m, n) int32.  m % tile_m == 0 and
    n % tile_n == 0 (callers pad; see ops.sweep_counts).
    """
    m, n = data.shape
    assert m % tile_m == 0, (m, tile_m)
    assert n % tile_n == 0, (n, tile_n)
    grid = (r_max, n // tile_n, m // tile_m)
    return pl.pallas_call(
        functools.partial(_kernel, max_q=max_q, r_max=r_max),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m,), lambda b, c, i: (i,)),
            pl.BlockSpec((tile_m,), lambda b, c, i: (i,)),
            pl.BlockSpec((tile_m, tile_n), lambda b, c, i: (i, c)),
        ],
        out_specs=pl.BlockSpec((1, max_q, tile_n * r_max),
                               lambda b, c, i: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((r_max, max_q, n * r_max), jnp.float32),
        interpret=interpret,
    )(cfg, child, data)
