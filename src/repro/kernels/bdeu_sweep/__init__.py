from .ops import sweep_counts, sweep_counts_restricted
from .ref import sweep_counts_ref
from .bdeu_sweep import sweep_counts_pallas
