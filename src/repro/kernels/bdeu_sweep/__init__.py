from .ops import delete_scores, sweep_counts, sweep_counts_restricted
from .ref import delete_scores_ref, sweep_counts_ref
from .bdeu_sweep import delete_scores_pallas, sweep_counts_pallas
