"""Pure-jnp oracle for the bdeu_sweep kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sweep_counts_ref(
    cfg: jax.Array, child: jax.Array, data: jax.Array, *, max_q: int, r_max: int
) -> jax.Array:
    """(r_max, max_q, n*r_max) joint counts; out-of-range rows ignored.

    counts[b, j0, x*r_max + a] = #(child=b, cfg0=j0, X_x=a), via one
    segment-sum of the (m, n*r_max) one-hot over the joint (b, j0) index.
    """
    m, n = data.shape
    oh_all = jax.nn.one_hot(data, r_max, dtype=jnp.float32).reshape(m, n * r_max)
    valid = (cfg >= 0) & (cfg < max_q) & (child >= 0) & (child < r_max)
    idx = jnp.where(valid,
                    jnp.clip(child, 0, r_max - 1) * max_q
                    + jnp.clip(cfg, 0, max_q - 1),
                    r_max * max_q)
    counts = jax.ops.segment_sum(
        jnp.where(valid[:, None], oh_all, 0.0), idx,
        num_segments=r_max * max_q + 1)
    return counts[:r_max * max_q].reshape(r_max, max_q, n * r_max)
