"""Pure-jnp oracles for the bdeu_sweep kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln


def sweep_counts_ref(
    cfg: jax.Array, child: jax.Array, data: jax.Array, *, max_q: int, r_max: int
) -> jax.Array:
    """(r_max, max_q, n*r_max) joint counts; out-of-range rows ignored.

    counts[b, j0, x*r_max + a] = #(child=b, cfg0=j0, X_x=a), via one
    segment-sum of the (m, n*r_max) one-hot over the joint (b, j0) index.
    """
    m, n = data.shape
    oh_all = jax.nn.one_hot(data, r_max, dtype=jnp.float32).reshape(m, n * r_max)
    valid = (cfg >= 0) & (cfg < max_q) & (child >= 0) & (child < r_max)
    idx = jnp.where(valid,
                    jnp.clip(child, 0, r_max - 1) * max_q
                    + jnp.clip(cfg, 0, max_q - 1),
                    r_max * max_q)
    counts = jax.ops.segment_sum(
        jnp.where(valid[:, None], oh_all, 0.0), idx,
        num_segments=r_max * max_q + 1)
    return counts[:r_max * max_q].reshape(r_max, max_q, n * r_max)


def bdeu_table_score(tbl: jax.Array, q, r, ess: float) -> jax.Array:
    """BDeu score of ONE dense (Q, R) count table with true hyperparameters
    (q, r) — mirrors ``bdeu._bdeu_from_counts`` for a single family.

    Zero-count rows/cells (dense padding) contribute exactly 0
    (lgamma(N + a) - lgamma(a) = 0 at N = 0).  This is THE reduction shared
    by the VMEM-resident Pallas delete kernel and its jnp oracle — plain jnp
    ops, so it traces inside the kernel and on host alike; keeping it in one
    place means a numerical tweak cannot make them silently disagree.
    """
    a_j = ess / q
    a_jk = ess / (q * r)
    n_ij = jnp.sum(tbl, axis=1)
    term_j = jnp.sum(gammaln(a_j) - gammaln(n_ij + a_j))
    term_jk = jnp.sum(gammaln(tbl + a_jk) - gammaln(a_jk))
    return term_j + term_jk


def delete_scores_ref(
    cfg: jax.Array,
    child: jax.Array,
    cand_slot: jax.Array,
    slot_ar: jax.Array,
    slot_low: jax.Array,
    qr: jax.Array,
    *,
    max_q: int,
    r_pad: int,
    ess: float,
) -> jax.Array:
    """(K,) delete-candidate BDeu scores; the jnp oracle for
    ``delete_scores_pallas`` (same contract, segment-sum realization).

    Builds the ONE current-family (max_q, r_pad) table (out-of-range rows
    ignored, like ``sweep_counts_ref``), marginalizes it per parent slot with
    the digit-sum relabeling t(j0) = (j0 // (low*ar)) * low + (j0 % low),
    reduces each marginal to its BDeu score with the slot's (q_del, r)
    hyperparameters, and gathers per candidate through ``cand_slot`` —
    slot 0 is the unmarginalized base family.
    """
    n_slots = slot_ar.shape[0]
    valid = (cfg >= 0) & (cfg < max_q) & (child >= 0) & (child < r_pad)
    flat = jnp.where(valid,
                     jnp.clip(cfg, 0, max_q - 1) * r_pad
                     + jnp.clip(child, 0, r_pad - 1),
                     max_q * r_pad)
    counts = jax.ops.segment_sum(
        jnp.ones_like(flat, dtype=jnp.float32), flat,
        num_segments=max_q * r_pad + 1)[:max_q * r_pad]
    counts = counts.reshape(max_q, r_pad)

    r = qr[n_slots + 1]
    j0 = jnp.arange(max_q, dtype=jnp.int32)

    def slot_score(s):
        ar, low = slot_ar[s], slot_low[s]
        t = (j0 // (low * ar)) * low + (j0 % low)
        marg = jax.ops.segment_sum(counts, t, num_segments=max_q)
        return bdeu_table_score(marg, qr[1 + s], r, ess)

    scores = [bdeu_table_score(counts, qr[0], r, ess)]
    for s in range(n_slots):
        scores.append(slot_score(s))
    return jnp.take(jnp.stack(scores), cand_slot)
