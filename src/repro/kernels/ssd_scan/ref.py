"""Pure-jnp oracle for the SSD scan: the literal per-step recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, a, b, c):
    """x: (B, H, T, P), a: (B, H, T) log-decay, b/c: (B, H, T, N).

    S_t = exp(a_t) S_{t-1} + B_t x_t^T ;  y_t = C_t^T S_t.
    """
    bsz, h, t, p = x.shape
    n = b.shape[-1]

    def step(s, inp):
        xt, at, bt, ct = s_inp = inp
        s = jnp.exp(at)[..., None, None] * s + jnp.einsum(
            "bhn,bhp->bhnp", bt, xt)
        y = jnp.einsum("bhn,bhnp->bhp", ct, s)
        return s, y

    s0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 2, 0),
          jnp.moveaxis(a.astype(jnp.float32), 2, 0),
          jnp.moveaxis(b.astype(jnp.float32), 2, 0),
          jnp.moveaxis(c.astype(jnp.float32), 2, 0))
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype)


def ssd_final_state_ref(x, a, b, c):
    """Final (B, H, N, P) state — used to cross-check chunk stitching."""
    bsz, h, t, p = x.shape
    n = b.shape[-1]

    def step(s, inp):
        xt, at, bt = inp
        return jnp.exp(at)[..., None, None] * s + jnp.einsum(
            "bhn,bhp->bhnp", bt, xt), None

    s0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 2, 0),
          jnp.moveaxis(a.astype(jnp.float32), 2, 0),
          jnp.moveaxis(b.astype(jnp.float32), 2, 0))
    s, _ = jax.lax.scan(step, s0, xs)
    return s
