from .ops import ssd_scan
from .ref import ssd_scan_ref, ssd_final_state_ref
from .ssd_scan import ssd_scan_pallas
