"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

Recurrence (per batch b, head h; state S in R^{N x P}):

    S_t = exp(a_t) * S_{t-1} + B_t x_t^T          a_t: log-decay scalar
    y_t = C_t^T S_t

The SSD insight (Dao & Gu 2024): split the sequence into chunks of length Q.
Within a chunk the output is an attention-like quadratic form with a causal
decay mask; across chunks only the (N, P) state is carried:

    cs_i           = cumsum(a)_i                      (inclusive, per chunk)
    y_intra        = ((C B^T) o L) X,   L[i,j] = exp(cs_i - cs_j) [i >= j]
    y_inter[i]     = exp(cs_i) * C_i S_prev
    S_new          = exp(cs_last) S_prev + sum_j exp(cs_last - cs_j) B_j x_j^T

TPU mapping: grid (B, H, T//Q), chunk index innermost & sequential; the
(N, P) running state lives in VMEM scratch; each grid step does three
MXU contractions ((Q,N)@(N,Q), (Q,Q)@(Q,P), (N,Q)@(Q,P)) — arithmetic
intensity scales with Q, chosen so all chunk tensors fit VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)      # (Q, P)
    a = a_ref[0, 0].astype(jnp.float32)      # (Q,)
    bmat = b_ref[0, 0].astype(jnp.float32)   # (Q, N)
    cmat = c_ref[0, 0].astype(jnp.float32)   # (Q, N)

    cs = jnp.cumsum(a)                       # (Q,) inclusive
    # L[i, j] = exp(cs_i - cs_j) for i >= j else 0
    li = cs[:, None] - cs[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_mask = rows >= cols
    l_decay = jnp.where(l_mask, jnp.exp(jnp.where(l_mask, li, 0.0)), 0.0)

    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y_intra = jax.lax.dot_general(cb * l_decay, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (Q, P)

    s_prev = state_ref[...]                  # (N, P)
    y_inter = jnp.exp(cs)[:, None] * jax.lax.dot_general(
        cmat, s_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (Q, P)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    w = jnp.exp(cs[-1] - cs)[:, None] * bmat          # (Q, N)
    state_ref[...] = jnp.exp(cs[-1]) * s_prev + jax.lax.dot_general(
        w, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (N, P)


def ssd_scan_pallas(
    x: jax.Array,      # (B, H, T, P)
    a: jax.Array,      # (B, H, T) log-decay
    b: jax.Array,      # (B, H, T, N)
    c: jax.Array,      # (B, H, T, N)
    *, chunk: int = 128, interpret: bool = True,
) -> jax.Array:
    bsz, h, t, p = x.shape
    n = b.shape[-1]
    if t % chunk != 0:
        raise ValueError(
            f"ssd_scan_pallas: sequence length t={t} must be a multiple "
            f"of chunk={chunk} (pad the time axis before calling)")
    grid = (bsz, h, t // chunk)

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda i, j, ic: (i, j, ic, 0)),
            pl.BlockSpec((1, 1, chunk), lambda i, j, ic: (i, j, ic)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, j, ic: (i, j, ic, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, j, ic: (i, j, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p), lambda i, j, ic: (i, j, ic, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)
