"""Jit'd public wrapper for the SSD scan Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan_pallas
from .ref import ssd_scan_ref


@partial(jax.jit, static_argnames=("chunk", "interpret", "use_ref"))
def ssd_scan(
    x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
    *, chunk: int = 128, interpret: bool = True, use_ref: bool = False,
) -> jax.Array:
    """Chunked SSD scan with automatic T padding.

    Padding is appended with a = 0 (decay 1) and B = 0, so padded steps
    neither write state nor emit real outputs; padded rows are sliced off.
    """
    if use_ref:
        return ssd_scan_ref(x, a, b, c)
    bsz, h, t, p = x.shape
    t_pad = ((t + chunk - 1) // chunk) * chunk
    if t_pad != t:
        pad = ((0, 0), (0, 0), (0, t_pad - t), (0, 0))
        x = jnp.pad(x, pad)
        b = jnp.pad(b, pad)
        c = jnp.pad(c, pad)
        a = jnp.pad(a, ((0, 0), (0, 0), (0, t_pad - t)))
    out = ssd_scan_pallas(x, a, b, c, chunk=chunk, interpret=interpret)
    return out[:, :, :t, :]
