"""Pure-jnp oracle for flash attention (GQA + causal)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D)."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, s), dtype=bool), k=s - t)
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhts,bhsd->bhtd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
