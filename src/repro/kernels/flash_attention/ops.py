"""Jit'd public wrapper for the flash attention Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import attention_ref


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret",
                                   "use_ref"))
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, block_q: int = 128, block_k: int = 128,
    interpret: bool = True, use_ref: bool = False,
) -> jax.Array:
    """Blocked attention with automatic seq padding.

    Padding correctness: padded KV columns receive -inf logits only via the
    causal mask when they sit beyond real rows; for the non-causal case we
    mask them explicitly by padding K with +inf-free zeros and masking in the
    kernel is unnecessary because padded q rows are sliced away and padded k
    rows would perturb softmax — so here we require exact multiples for
    non-causal and pad only causal inputs (padded kv sits after all real
    queries and is never attended).
    """
    if use_ref:
        return attention_ref(q, k, v, causal=causal)
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    bq = min(block_q, _round_up(t, 8))
    bk = min(block_k, _round_up(s, 8))
    t_pad = _round_up(t, bq)
    s_pad = _round_up(s, bk)
    if (t_pad != t or s_pad != s) and not causal:
        raise ValueError("non-causal path requires block-aligned seq lens")
    if causal and t != s:
        raise ValueError("causal flash kernel is for square self-attention "
                         "(prefill/train); decode uses the XLA path")
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :, :t, :]
