"""Pallas TPU kernel: blocked causal flash attention with GQA.

Online-softmax attention tiled for VMEM: the query block plus one KV block
live in VMEM at a time; running max / normalizer / accumulator persist in
VMEM scratch across the (sequential) KV grid dimension.

Grid:      (B, Hq, T//BQ, S//BK) — KV block index innermost (sequential).
BlockSpec: q/out (1, 1, BQ, D); k/v (1, 1, BK, D) with the head index mapped
           through h // (Hq // Hkv) — GQA sharing without materializing
           repeated KV.
Scratch:   acc (BQ, D) f32, m/l (BQ, 128) f32 (lane-padded running stats).

Used for train/prefill (square or rectangular T x S).  Decode (T == 1) is
intentionally left to XLA — a single-row gather-dominated contraction is
memory-bound and fuses well without a custom kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, causal: bool, block_q: int, block_k: int,
            seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # Skip fully-masked KV blocks under causality.
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)            # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (BQ, BK)

        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)

        m_prev = m_ref[:, :1]                           # (BQ, 1)
        m_cur = s.max(axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (BQ, BK)
        corr = jnp.exp(m_prev - m_new)                  # (BQ, 1)
        l_new = corr * l_ref[:, :1] + p.sum(axis=1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_ref[:, :1]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, scale: float | None = None,
    block_q: int = 128, block_k: int = 128, interpret: bool = True,
) -> jax.Array:
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0.

    T % block_q == 0 and S % block_k == 0 (ops.py pads).
    """
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    if hkv == 0 or hq % hkv != 0:
        raise ValueError(
            f"flash_attention_pallas: query heads hq={hq} must be a "
            f"positive multiple of KV heads hkv={hkv} (GQA grouping)")
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    grid = (b, hq, t // block_q, s // block_k)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, seq_k=s)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),    # acc
            pltpu.VMEM((block_q, 128), jnp.float32),  # running max
            pltpu.VMEM((block_q, 128), jnp.float32),  # running sum
        ],
        interpret=interpret,
    )(q, k, v)
