"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference, wall time on
CPU + analytic flops.  Interpret-mode timing measures correctness-path cost,
not TPU performance — the TPU-relevant numbers are the roofline terms in
EXPERIMENTS.md; this harness checks call overhead and validates shapes at
benchmark scale.

``--sweep-json PATH`` additionally times the fused all-candidate BDeu sweeps
against the per-candidate loop engine at paper scale — the FES insert column
(one joint contraction), the BES delete column (one family-table build,
marginalized per parent slot), the restricted-W ring column (contraction
gathered down to the W = |E_i| candidates before it runs) and the
compiled-ring per-round matrix (``ring_compiled``: the (W, n) pid_table
sweep the ges_jit/shard_map ring initializes each round from, vs the old
full-n matrix) — and writes a machine-readable trajectory record; later PRs
diff this file to track the sweep's perf over time.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_all():
    rows = []
    key = jax.random.PRNGKey(0)

    # bdeu_count: paper-scale single-candidate table (m=5000, q=4096)
    from repro.kernels.bdeu_count import contingency_counts
    cfgv = jax.random.randint(key, (5000,), 0, 4096, dtype=jnp.int32)
    child = jax.random.randint(key, (5000,), 0, 4, dtype=jnp.int32)
    for impl, use_ref in (("pallas_interp", False), ("jnp_ref", True)):
        us = _time(lambda a, b: contingency_counts(
            a, b, max_q=4096, r_max=4, use_ref=use_ref), cfgv, child)
        rows.append((f"bdeu_count/{impl}", us,
                     "m=5000 q=4096 r=4; flops≈%.2e" % (2 * 5000 * 4096)))

    # bdeu_sweep: fused all-candidate sweep counts, pallas-interp vs jnp ref
    from repro.kernels.bdeu_sweep import sweep_counts
    ks = jax.random.split(key, 3)
    cfg0 = jax.random.randint(ks[0], (2560,), 0, 128, dtype=jnp.int32)
    childv = jax.random.randint(ks[1], (2560,), 0, 3, dtype=jnp.int32)
    datav = jax.random.randint(ks[2], (2560, 64), 0, 3, dtype=jnp.int32)
    for impl, use_ref in (("pallas_interp", False), ("jnp_ref", True)):
        us = _time(lambda a, b, c: sweep_counts(
            a, b, c, max_q=128, r_max=3, use_ref=use_ref), cfg0, childv, datav)
        rows.append((f"bdeu_sweep/{impl}", us,
                     "m=2560 n=64 q=128 r=3; flops≈%.2e"
                     % (2 * 2560 * 128 * 64 * 3)))

    # flash attention: one 1k x 1k head block
    from repro.kernels.flash_attention import flash_attention
    q = jax.random.normal(key, (1, 4, 1024, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2, 1024, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2, 1024, 64), jnp.float32)
    for impl, use_ref in (("pallas_interp", False), ("jnp_ref", True)):
        us = _time(lambda a, b, c: flash_attention(
            a, b, c, causal=True, use_ref=use_ref), q, k, v)
        rows.append((f"flash_attention/{impl}", us,
                     "B1 H4 T1k D64; flops≈%.2e" % (4 * 4 * 1024 * 1024 * 64)))

    # ssd scan: zamba-like chunk
    from repro.kernels.ssd_scan import ssd_scan
    x = jax.random.normal(key, (1, 4, 1024, 64), jnp.float32)
    a = -jax.nn.softplus(jax.random.normal(key, (1, 4, 1024)))
    b = jax.random.normal(key, (1, 4, 1024, 64)) * 0.3
    c = jax.random.normal(key, (1, 4, 1024, 64)) * 0.3
    for impl, use_ref in (("pallas_interp", False), ("jnp_ref", True)):
        us = _time(lambda *t: ssd_scan(*t, chunk=128, use_ref=use_ref),
                   x, a, b, c)
        rows.append((f"ssd_scan/{impl}", us, "B1 H4 T1k P64 N64"))
    return rows


def bench_sweep(n: int = 400, m: int = 5000, max_q: int = 256,
                seed: int = 0, reps: int = 3, w: int = 32) -> dict:
    """Fused vs per-candidate-loop sweep columns at paper scale.

    Times one child's candidate columns through the unified engine
    (core/sweeps.sweep): the loop engine dispatches one contingency build
    per candidate; the fused engines dispatch

    * insert: ONE joint contraction (jnp: one segment-sum; kernel: r_max
      matmuls),
    * delete: ONE family-table build, every candidate table read off it by
      marginalizing one parent slot (zero re-counting),
    * restricted-W (ring E_i): the insert contraction gathered down to the W
      candidate columns BEFORE it runs — cost tracks W, not n.

    CPU wall time — the dispatch-count ratio is the hardware-independent
    part.
    """
    from repro.core.sweeps import sweep

    rng = np.random.default_rng(seed)
    arities = rng.integers(2, 4, size=n)
    data = np.stack([rng.integers(0, a, size=m) for a in arities], 1)
    adj = np.zeros((n, n), dtype=np.int8)
    adj[1, 0] = adj[2, 0] = 1          # child 0 with two parents (q0 <= 9)
    r_max = int(arities.max())
    dj = jnp.asarray(data.astype(np.int32))
    aj = jnp.asarray(arities.astype(np.int32))
    adjj = jnp.asarray(adj)
    kw = dict(ess=10.0, max_q=max_q, r_max=r_max)

    def col(kind, impl, pids=None):
        return _time(lambda a: sweep(dj, aj, a, kind=kind, y=0, pids=pids,
                                     counts_impl=impl, **kw), adjj, reps=reps)

    rec = {"n": n, "m": m, "max_q": max_q, "r_max": r_max,
           "platform": jax.default_backend(),
           # Static program-structure counts (not runtime counters): the loop
           # engine builds one (max_q, r_max) contingency table per candidate
           # (on TPU: n bdeu_count kernel launches per column); the fused
           # engine builds ALL candidate tables in one joint contraction (one
           # grid-batched bdeu_sweep launch / one segment-sum in the timed
           # jnp CPU mirrors below).
           "sweep_table_builds": {"loop_segment": n, "fused": 1},
           "dispatch_ratio": n,
           "engines": {}}
    for name, impl in (("loop_segment", "segment"), ("fused", "fused")):
        us = col("insert", impl)
        rec["engines"][name] = {
            "sweep_us": round(us, 1),
            "score_evals_per_s": round(n / (us * 1e-6), 1),
        }
    rec["speedup_fused_vs_loop"] = round(
        rec["engines"]["loop_segment"]["sweep_us"]
        / rec["engines"]["fused"]["sweep_us"], 2)

    # BES delete column: loop = n table builds; fused = ONE family-table
    # build + an O(n * max_q * r_max) marginalization, no re-counting.
    rec["delete"] = {"sweep_table_builds": {"loop_segment": n, "fused": 1},
                     "engines": {}}
    for name, impl in (("loop_segment", "segment"), ("fused", "fused"),
                       ("fused_pallas", "fused_pallas")):
        us = col("delete", impl)
        rec["delete"]["engines"][name] = {
            "sweep_us": round(us, 1),
            "score_evals_per_s": round(n / (us * 1e-6), 1),
        }
    rec["delete"]["speedup_fused_vs_loop"] = round(
        rec["delete"]["engines"]["loop_segment"]["sweep_us"]
        / rec["delete"]["engines"]["fused"]["sweep_us"], 2)

    # Restricted-W ring column (|E_i| ~ n/k candidates): fused cost must
    # track W, not n — record the fused full-n column for the scaling ratio.
    pids = jnp.asarray(rng.choice(np.arange(1, n), size=w, replace=False)
                       .astype(np.int32))
    rec["restricted"] = {"W": w, "engines": {}}
    for name, impl in (("loop_segment", "segment"), ("fused", "fused"),
                       ("fused_pallas", "fused_pallas")):
        us = col("insert", impl, pids=pids)
        rec["restricted"]["engines"][name] = {
            "sweep_us": round(us, 1),
            "score_evals_per_s": round(w / (us * 1e-6), 1),
        }
    rec["restricted"]["fused_full_n_us"] = rec["engines"]["fused"]["sweep_us"]
    rec["restricted"]["fused_w_cost_fraction_of_full_n"] = round(
        rec["restricted"]["engines"]["fused"]["sweep_us"]
        / rec["engines"]["fused"]["sweep_us"], 3)

    # Compiled-ring per-round sweep: the (W, n) pid_table matrix that the
    # ges_jit/shard_map ring now initializes each round from (every child's
    # W = |E_i| candidates) vs the old full-n (n, n) matrix it used to
    # sweep-then-mask.  Per-round cost must track W, not n; trajectory
    # identity to the full-n path is asserted by tests (test_ges /
    # test_sweeps), this records the cost side.
    from repro.core.partition import pid_table_from_allowed

    allowed = np.zeros((n, n), dtype=bool)
    for y in range(n):
        cand = rng.choice(np.delete(np.arange(n), y), size=w, replace=False)
        allowed[cand, y] = True
    tbl = jnp.asarray(pid_table_from_allowed(allowed))

    def mat(impl, pid_table=None):
        # multi-rep like every other sweep entry: later PRs diff this ratio,
        # and a single sample of a multi-second sweep is scheduler-noise
        return _time(lambda a: sweep(dj, aj, a, kind="insert",
                                     pid_table=pid_table, counts_impl=impl,
                                     **kw), adjj, reps=reps)

    full_us = mat("fused")
    res_us = mat("fused", pid_table=tbl)
    rec["ring_compiled"] = {
        "W": w, "w_over_n": round(w / n, 3),
        "counts_impl": "fused",
        "full_n_round_us": round(full_us, 1),
        "restricted_round_us": round(res_us, 1),
        "w_cost_fraction_of_full_n": round(res_us / full_us, 3),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep-json", default=None,
                    help="also run the fused-vs-loop sweep bench at paper "
                         "scale and write the record to this path")
    ap.add_argument("--sweep-n", type=int, default=400)
    ap.add_argument("--sweep-m", type=int, default=5000)
    args = ap.parse_args()
    for name, us, derived in bench_all():
        print(f"{name},{us:.0f},{derived}")
    if args.sweep_json:
        rec = bench_sweep(n=args.sweep_n, m=args.sweep_m)
        with open(args.sweep_json, "w") as f:
            json.dump(rec, f, indent=2)
            f.write("\n")
        print(f"bdeu_sweep/loop,{rec['engines']['loop_segment']['sweep_us']:.0f},"
              f"n={rec['n']} m={rec['m']}")
        print(f"bdeu_sweep/fused,{rec['engines']['fused']['sweep_us']:.0f},"
              f"speedup={rec['speedup_fused_vs_loop']}x "
              f"dispatch_ratio={rec['dispatch_ratio']}x")
        d = rec["delete"]
        print(f"bdeu_sweep/delete_loop,"
              f"{d['engines']['loop_segment']['sweep_us']:.0f},"
              f"{rec['n']} table builds")
        print(f"bdeu_sweep/delete_fused,{d['engines']['fused']['sweep_us']:.0f},"
              f"speedup={d['speedup_fused_vs_loop']}x (1 table build)")
        s = rec["restricted"]
        print(f"bdeu_sweep/restricted_fused,"
              f"{s['engines']['fused']['sweep_us']:.0f},"
              f"W={s['W']} cost={s['fused_w_cost_fraction_of_full_n']}"
              f" of full-n fused")
        r = rec["ring_compiled"]
        print(f"bdeu_sweep/ring_compiled,{r['restricted_round_us']:.0f},"
              f"(W,n) pid_table round W={r['W']} "
              f"cost={r['w_cost_fraction_of_full_n']} of full-n round")


if __name__ == "__main__":
    main()
