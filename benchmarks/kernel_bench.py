"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference, wall time on
CPU + analytic flops.  Interpret-mode timing measures correctness-path cost,
not TPU performance — the TPU-relevant numbers are the roofline terms in
EXPERIMENTS.md; this harness checks call overhead and validates shapes at
benchmark scale.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_all():
    rows = []
    key = jax.random.PRNGKey(0)

    # bdeu_count: paper-scale single-candidate table (m=5000, q=4096)
    from repro.kernels.bdeu_count import contingency_counts
    cfgv = jax.random.randint(key, (5000,), 0, 4096, dtype=jnp.int32)
    child = jax.random.randint(key, (5000,), 0, 4, dtype=jnp.int32)
    for impl, use_ref in (("pallas_interp", False), ("jnp_ref", True)):
        us = _time(lambda a, b: contingency_counts(
            a, b, max_q=4096, r_max=4, use_ref=use_ref), cfgv, child)
        rows.append((f"bdeu_count/{impl}", us,
                     "m=5000 q=4096 r=4; flops≈%.2e" % (2 * 5000 * 4096)))

    # flash attention: one 1k x 1k head block
    from repro.kernels.flash_attention import flash_attention
    q = jax.random.normal(key, (1, 4, 1024, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2, 1024, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2, 1024, 64), jnp.float32)
    for impl, use_ref in (("pallas_interp", False), ("jnp_ref", True)):
        us = _time(lambda a, b, c: flash_attention(
            a, b, c, causal=True, use_ref=use_ref), q, k, v)
        rows.append((f"flash_attention/{impl}", us,
                     "B1 H4 T1k D64; flops≈%.2e" % (4 * 4 * 1024 * 1024 * 64)))

    # ssd scan: zamba-like chunk
    from repro.kernels.ssd_scan import ssd_scan
    x = jax.random.normal(key, (1, 4, 1024, 64), jnp.float32)
    a = -jax.nn.softplus(jax.random.normal(key, (1, 4, 1024)))
    b = jax.random.normal(key, (1, 4, 1024, 64)) * 0.3
    c = jax.random.normal(key, (1, 4, 1024, 64)) * 0.3
    for impl, use_ref in (("pallas_interp", False), ("jnp_ref", True)):
        us = _time(lambda *t: ssd_scan(*t, chunk=128, use_ref=use_ref),
                   x, a, b, c)
        rows.append((f"ssd_scan/{impl}", us, "B1 H4 T1k P64 N64"))
    return rows


def main():
    for name, us, derived in bench_all():
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
