"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference, wall time on
CPU + analytic flops.  Interpret-mode timing measures correctness-path cost,
not TPU performance — the TPU-relevant numbers are the roofline terms in
EXPERIMENTS.md; this harness checks call overhead and validates shapes at
benchmark scale.

``--sweep-json PATH`` additionally times the fused all-candidate BDeu sweeps
against the per-candidate loop engine at paper scale — the FES insert column
(one joint contraction), the BES delete column (one family-table build,
marginalized per parent slot), the VMEM-resident Pallas delete column
(``delete_pallas``: table build + per-slot marginalization + BDeu reduction
in ONE kernel, with HBM-traffic accounting vs the two-step
build-then-marginalize path it replaced), the restricted-W ring column
(contraction gathered down to the W = |E_i| candidates before it runs) and
the compiled-ring per-round matrix (``ring_compiled``: the (W, n) pid_table
sweep the ges_jit/shard_map ring initializes each round from, vs the old
full-n matrix) — and writes a machine-readable trajectory record; later PRs
diff this file to track the sweep's perf over time.

The record also carries a ``fusion`` entry: the OTHER per-round ring
operator — the sigma-consistent edge union (core/fusion.py) — timed host vs
jit at the same n, against the pre-refactor traceable baseline that
recomputed the full longest-path depth per covered reversal (kept inline
below as ``_legacy_fuse_jit``), plus the fusion/sweep per-round cost ratio
that decides whether compiled ring rounds are sweep-bound or fusion-bound.

``async_ring`` records the asynchronous double-buffered ring
(core/ring_async): per-round wall vs the warm lockstep compiled ring on
the same seeded partition, the permute-wait/fuse/sweep phase breakdown
per member (blocked transfer wait vs sweep time is the overlap
evidence), trajectory parity, and a kill-one-member elastic run
converging with k-1 members.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

# The data_sharded sweep entry times d-device data-axis meshes (d up to 4);
# XLA_FLAGS must be set before the backend initializes, so peek argv before
# the jax import (only when the sweep record was asked for — the plain
# kernel table keeps the default single-device platform).
if "--sweep-json" in " ".join(sys.argv[1:]) and \
        "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4").strip()

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_all():
    rows = []
    key = jax.random.PRNGKey(0)

    # bdeu_count: paper-scale single-candidate table (m=5000, q=4096)
    from repro.kernels.bdeu_count import contingency_counts
    cfgv = jax.random.randint(key, (5000,), 0, 4096, dtype=jnp.int32)
    child = jax.random.randint(key, (5000,), 0, 4, dtype=jnp.int32)
    for impl, use_ref in (("pallas_interp", False), ("jnp_ref", True)):
        us = _time(lambda a, b: contingency_counts(
            a, b, max_q=4096, r_max=4, use_ref=use_ref), cfgv, child)
        rows.append((f"bdeu_count/{impl}", us,
                     "m=5000 q=4096 r=4; flops≈%.2e" % (2 * 5000 * 4096)))

    # bdeu_sweep: fused all-candidate sweep counts, pallas-interp vs jnp ref
    from repro.kernels.bdeu_sweep import sweep_counts
    ks = jax.random.split(key, 3)
    cfg0 = jax.random.randint(ks[0], (2560,), 0, 128, dtype=jnp.int32)
    childv = jax.random.randint(ks[1], (2560,), 0, 3, dtype=jnp.int32)
    datav = jax.random.randint(ks[2], (2560, 64), 0, 3, dtype=jnp.int32)
    for impl, use_ref in (("pallas_interp", False), ("jnp_ref", True)):
        us = _time(lambda a, b, c: sweep_counts(
            a, b, c, max_q=128, r_max=3, use_ref=use_ref), cfg0, childv, datav)
        rows.append((f"bdeu_sweep/{impl}", us,
                     "m=2560 n=64 q=128 r=3; flops≈%.2e"
                     % (2 * 2560 * 128 * 64 * 3)))

    # flash attention: one 1k x 1k head block
    from repro.kernels.flash_attention import flash_attention
    q = jax.random.normal(key, (1, 4, 1024, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2, 1024, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2, 1024, 64), jnp.float32)
    for impl, use_ref in (("pallas_interp", False), ("jnp_ref", True)):
        us = _time(lambda a, b, c: flash_attention(
            a, b, c, causal=True, use_ref=use_ref), q, k, v)
        rows.append((f"flash_attention/{impl}", us,
                     "B1 H4 T1k D64; flops≈%.2e" % (4 * 4 * 1024 * 1024 * 64)))

    # ssd scan: zamba-like chunk
    from repro.kernels.ssd_scan import ssd_scan
    x = jax.random.normal(key, (1, 4, 1024, 64), jnp.float32)
    a = -jax.nn.softplus(jax.random.normal(key, (1, 4, 1024)))
    b = jax.random.normal(key, (1, 4, 1024, 64)) * 0.3
    c = jax.random.normal(key, (1, 4, 1024, 64)) * 0.3
    for impl, use_ref in (("pallas_interp", False), ("jnp_ref", True)):
        us = _time(lambda *t: ssd_scan(*t, chunk=128, use_ref=use_ref),
                   x, a, b, c)
        rows.append((f"ssd_scan/{impl}", us, "B1 H4 T1k P64 N64"))
    return rows


def _legacy_fuse_jit(g_own, g_pred):
    """Pre-refactor traceable fusion (PR 3 state), kept ONLY as the benchmark
    baseline for ``fusion.speedup_jit_vs_prerefactor``: GHO cost re-summed
    from both (n, n) masks at every position, and a full longest-path depth
    recompute — an O(n)-step fori_loop over the whole matrix — inside every
    covered-edge reversal.  core/fusion.py's engines replaced both with
    incremental maintenance."""
    n = g_own.shape[0]

    def depth_full(adj, in_s):
        sub = adj.astype(bool) & in_s[:, None] & in_s[None, :]

        def body(_, depth):
            parent_d = jnp.where(sub, depth[:, None], -1)
            return jnp.where(in_s,
                             jnp.maximum(depth, parent_d.max(axis=0) + 1), -1)

        return jax.lax.fori_loop(0, n, body, jnp.where(in_s, 0, -1))

    def gho(adj_a, adj_b):
        a, b = adj_a.astype(jnp.int32), adj_b.astype(jnp.int32)

        def body(step, carry):
            rank, remaining = carry
            rem = remaining.astype(jnp.int32)
            cost = (a * rem[None, :]).sum(1) + (b * rem[None, :]).sum(1)
            cost = jnp.where(remaining, cost, jnp.iinfo(jnp.int32).max)
            v = jnp.argmin(cost)
            return rank.at[v].set(n - 1 - step), remaining.at[v].set(False)

        rank, _ = jax.lax.fori_loop(
            0, n, body, (jnp.zeros(n, jnp.int32), jnp.ones(n, bool)))
        return rank

    def sigma(adj, rank):
        order = jnp.argsort(-rank)

        def process_node(step, adj):
            v = order[step]
            in_s = rank <= rank[v]

            def cond(adj):
                return (jnp.take(adj, v, axis=0).astype(bool) & in_s).any()

            def body(adj):
                out = jnp.take(adj, v, axis=0).astype(bool) & in_s
                depth = depth_full(adj, in_s)
                w = jnp.argmin(jnp.where(out, depth,
                                         jnp.iinfo(jnp.int32).max))
                pa_v = jnp.take(adj, v, axis=1).astype(bool)
                pa_w = jnp.take(adj, w, axis=1).astype(bool)
                idx = jnp.arange(n)
                add_to_w = pa_v & ~pa_w & (idx != w) & (idx != v)
                add_to_v = pa_w & ~pa_v & (idx != v) & (idx != w)
                adj = adj.at[:, w].set((pa_w | add_to_w).astype(adj.dtype))
                pa_v2 = jnp.take(adj, v, axis=1).astype(bool)
                adj = adj.at[:, v].set((pa_v2 | add_to_v).astype(adj.dtype))
                return adj.at[v, w].set(0).at[w, v].set(1)

            return jax.lax.while_loop(cond, body, adj)

        return jax.lax.fori_loop(0, n, process_node, adj)

    rank = gho(g_own, g_pred)
    ta = sigma(g_own.astype(jnp.int8), rank)
    tb = sigma(g_pred.astype(jnp.int8), rank)
    return (ta.astype(bool) | tb.astype(bool)).astype(jnp.int8)


def bench_fusion(n: int = 400, seed: int = 0, reps: int = 3,
                 legacy: bool = True) -> dict:
    """Per-round ring fusion (sigma-consistent edge union) at paper scale.

    Times the unified engine (core/fusion.py) host vs jit on a sparse random
    DAG pair, and — when ``legacy`` — the pre-refactor traceable baseline
    (full depth recompute per reversal) for the recorded speedup.
    """
    from repro.core import fusion
    from repro.core.dag import random_dag_np

    rng = np.random.default_rng(seed)
    a = random_dag_np(rng, n, int(1.2 * n), max_parents=3)
    b = random_dag_np(rng, n, int(1.2 * n), max_parents=3)
    a8 = jnp.asarray(a.astype(np.int8))
    b8 = jnp.asarray(b.astype(np.int8))

    host_us = _time(lambda x, y: fusion.fusion_edge_union(x, y,
                                                          engine="host"),
                    a, b, reps=reps)
    jit_us = _time(jax.jit(fusion.fuse_trace), a8, b8, reps=reps)
    rec = {"n": n,
           "edges": {"a": int(a.sum()), "b": int(b.sum())},
           "host_us": round(host_us, 1),
           "jit_us": round(jit_us, 1)}
    if legacy:
        # The baseline is minutes-scale at n=400 — time it by hand with ONE
        # warmup + ONE rep (_time's warmup would execute it twice more).
        fn = jax.jit(_legacy_fuse_jit)
        jax.block_until_ready(fn(a8, b8))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(a8, b8))
        legacy_us = (time.perf_counter() - t0) * 1e6
        rec["legacy_jit_us"] = round(legacy_us, 1)
        rec["speedup_jit_vs_prerefactor"] = round(legacy_us / jit_us, 2)
    return rec


def bench_sweep(n: int = 400, m: int = 5000, max_q: int = 256,
                seed: int = 0, reps: int = 3, w: int = 32) -> dict:
    """Fused vs per-candidate-loop sweep columns at paper scale.

    Times one child's candidate columns through the unified engine
    (core/sweeps.sweep): the loop engine dispatches one contingency build
    per candidate; the fused engines dispatch

    * insert: ONE joint contraction (jnp: one segment-sum; kernel: r_max
      matmuls),
    * delete: ONE family-table build, every candidate table read off it by
      marginalizing one parent slot (zero re-counting),
    * restricted-W (ring E_i): the insert contraction gathered down to the W
      candidate columns BEFORE it runs — cost tracks W, not n.

    CPU wall time — the dispatch-count ratio is the hardware-independent
    part.
    """
    from repro.core.sweeps import sweep

    rng = np.random.default_rng(seed)
    arities = rng.integers(2, 4, size=n)
    data = np.stack([rng.integers(0, a, size=m) for a in arities], 1)
    adj = np.zeros((n, n), dtype=np.int8)
    adj[1, 0] = adj[2, 0] = 1          # child 0 with two parents (q0 <= 9)
    r_max = int(arities.max())
    dj = jnp.asarray(data.astype(np.int32))
    aj = jnp.asarray(arities.astype(np.int32))
    adjj = jnp.asarray(adj)
    kw = dict(ess=10.0, max_q=max_q, r_max=r_max)

    def col(kind, impl, pids=None):
        return _time(lambda a: sweep(dj, aj, a, kind=kind, y=0, pids=pids,
                                     counts_impl=impl, **kw), adjj, reps=reps)

    rec = {"n": n, "m": m, "max_q": max_q, "r_max": r_max,
           "platform": jax.default_backend(),
           # Static program-structure counts (not runtime counters): the loop
           # engine builds one (max_q, r_max) contingency table per candidate
           # (on TPU: n bdeu_count kernel launches per column); the fused
           # engine builds ALL candidate tables in one joint contraction (one
           # grid-batched bdeu_sweep launch / one segment-sum in the timed
           # jnp CPU mirrors below).
           "sweep_table_builds": {"loop_segment": n, "fused": 1},
           "dispatch_ratio": n,
           "engines": {}}
    for name, impl in (("loop_segment", "segment"), ("fused", "fused")):
        us = col("insert", impl)
        rec["engines"][name] = {
            "sweep_us": round(us, 1),
            "score_evals_per_s": round(n / (us * 1e-6), 1),
        }
    rec["speedup_fused_vs_loop"] = round(
        rec["engines"]["loop_segment"]["sweep_us"]
        / rec["engines"]["fused"]["sweep_us"], 2)

    # BES delete column: loop = n table builds; fused = ONE family-table
    # build + an O(n * max_q * r_max) marginalization, no re-counting.
    rec["delete"] = {"sweep_table_builds": {"loop_segment": n, "fused": 1},
                     "engines": {}}
    for name, impl in (("loop_segment", "segment"), ("fused", "fused"),
                       ("fused_pallas", "fused_pallas")):
        us = col("delete", impl)
        rec["delete"]["engines"][name] = {
            "sweep_us": round(us, 1),
            "score_evals_per_s": round(n / (us * 1e-6), 1),
        }
    rec["delete"]["speedup_fused_vs_loop"] = round(
        rec["delete"]["engines"]["loop_segment"]["sweep_us"]
        / rec["delete"]["engines"]["fused"]["sweep_us"], 2)

    # VMEM-resident Pallas delete column (kernels/bdeu_sweep.delete_scores:
    # the one family table accumulates in VMEM scratch, every parent-slot
    # marginal is reduced to its BDeu score in-kernel, only the (n,) column
    # is written) vs the two-step path it replaced — bdeu_count Pallas table
    # build, then jnp marginalization — which round-trips the (max_q, r_max)
    # table through HBM once per column.  Interpret-mode wall time measures
    # correctness-path cost; the HBM-byte accounting (analytic, logical f32
    # sizes) is the hardware-independent part.
    from repro.core import bdeu as _bdeu

    @jax.jit
    def two_step_delete_col(a):
        # counts_impl="pallas" routes fused_delete_scores through its
        # non-kernel branch: bdeu_count Pallas table build + jnp
        # marginalization — the EXACT two-step engine the VMEM kernel
        # replaced, so the baseline can never drift from the real path
        return _bdeu.fused_delete_scores(
            dj, aj, jnp.int32(0), a.astype(bool)[:, 0], 10.0, max_q, r_max,
            counts_impl="pallas")

    two_step_us = _time(two_step_delete_col, adjj, reps=reps)
    vmem_us = rec["delete"]["engines"]["fused_pallas"]["sweep_us"]
    table = 4 * max_q * r_max                      # logical f32 family table
    inputs = 8 * m                                 # cfg + child int32 reads
    two_step_bytes = (inputs + table               # table write to HBM
                      + n * table                  # broadcast read, n ways
                      + 2 * n * table              # marginal slab write+read
                      + 4 * n)                     # column write
    vmem_bytes = inputs + 4 * n                    # table/marginals stay VMEM
    rec["delete_pallas"] = {
        "vmem_resident_us": vmem_us,
        "two_step_us": round(two_step_us, 1),
        "speedup_vmem_vs_two_step": round(two_step_us / vmem_us, 2),
        "hbm_bytes": {
            "two_step": two_step_bytes,
            "vmem_resident": vmem_bytes,
            "traffic_ratio": round(two_step_bytes / vmem_bytes, 1),
        },
    }

    # Restricted-W ring column (|E_i| ~ n/k candidates): fused cost must
    # track W, not n — record the fused full-n column for the scaling ratio.
    pids = jnp.asarray(rng.choice(np.arange(1, n), size=w, replace=False)
                       .astype(np.int32))
    rec["restricted"] = {"W": w, "engines": {}}
    for name, impl in (("loop_segment", "segment"), ("fused", "fused"),
                       ("fused_pallas", "fused_pallas")):
        us = col("insert", impl, pids=pids)
        rec["restricted"]["engines"][name] = {
            "sweep_us": round(us, 1),
            "score_evals_per_s": round(w / (us * 1e-6), 1),
        }
    rec["restricted"]["fused_full_n_us"] = rec["engines"]["fused"]["sweep_us"]
    rec["restricted"]["fused_w_cost_fraction_of_full_n"] = round(
        rec["restricted"]["engines"]["fused"]["sweep_us"]
        / rec["engines"]["fused"]["sweep_us"], 3)

    # Compiled-ring per-round sweep: the (W, n) pid_table matrix that the
    # ges_jit/shard_map ring now initializes each round from (every child's
    # W = |E_i| candidates) vs the old full-n (n, n) matrix it used to
    # sweep-then-mask.  Per-round cost must track W, not n; trajectory
    # identity to the full-n path is asserted by tests (test_ges /
    # test_sweeps), this records the cost side.
    from repro.core.partition import pid_table_from_allowed

    allowed = np.zeros((n, n), dtype=bool)
    for y in range(n):
        cand = rng.choice(np.delete(np.arange(n), y), size=w, replace=False)
        allowed[cand, y] = True
    tbl = jnp.asarray(pid_table_from_allowed(allowed))

    def mat(impl, pid_table=None):
        # multi-rep like every other sweep entry: later PRs diff this ratio,
        # and a single sample of a multi-second sweep is scheduler-noise
        return _time(lambda a: sweep(dj, aj, a, kind="insert",
                                     pid_table=pid_table, counts_impl=impl,
                                     **kw), adjj, reps=reps)

    full_us = mat("fused")
    res_us = mat("fused", pid_table=tbl)
    rec["ring_compiled"] = {
        "W": w, "w_over_n": round(w / n, 3),
        "counts_impl": "fused",
        "full_n_round_us": round(full_us, 1),
        "restricted_round_us": round(res_us, 1),
        "w_cost_fraction_of_full_n": round(res_us / full_us, 3),
    }

    # Fusion — the other per-round ring operator: host vs jit through the
    # unified core/fusion.py engine, the pre-refactor full-depth-recompute
    # baseline, and the fusion/sweep cost ratio of one compiled ring round
    # (one pairwise edge union + one (W, n) restricted sweep init).
    rec["fusion"] = bench_fusion(n=n, seed=seed, reps=reps)
    rec["fusion"]["fusion_over_sweep_round"] = round(
        rec["fusion"]["jit_us"]
        / rec["ring_compiled"]["restricted_round_us"], 3)
    return rec


def bench_data_sharded(n: int = 400, m: int = 5000, max_q: int = 256,
                       seed: int = 0, reps: int = 3,
                       shard_counts=(1, 2, 4)) -> dict:
    """Per-round insert-matrix sweep under d-way data-axis sharding at fixed
    GLOBAL m (core/sweeps ``data_shards``: each device contracts m/d rows,
    one psum merges the count tables).

    Two timings per d, because this container is a single CPU core:

    * ``mesh_round_us`` — the real d-(virtual-)device program.  All d shards
      still execute on one core, so this measures correctness-path overhead
      (shard_map + psum), NOT the d-way speedup real hardware gets.
    * ``per_device_round_us`` — a single-device sweep over the ceil(m/d)
      LOCAL rows, everything else fixed: the per-device work the mesh
      distributes, and the honest proxy for d-chip wall time (the psum'd
      (W, Q, R) tables are m-independent and tiny next to the contraction).

    ``per_round_speedup`` = per_device(d=1) / per_device(d), recorded for
    d=4 as the headline ``per_round_speedup_at_d4``.
    """
    from repro.core.sweeps import pad_data_rows, sweep

    rng = np.random.default_rng(seed)
    arities = rng.integers(2, 4, size=n)
    data = np.stack([rng.integers(0, a, size=m) for a in arities], 1)
    r_max = int(arities.max())
    adj = np.zeros((n, n), dtype=np.int8)
    adj[1, 0] = adj[2, 0] = 1
    dj = jnp.asarray(data.astype(np.int32))
    aj = jnp.asarray(arities.astype(np.int32))
    adjj = jnp.asarray(adj)
    kw = dict(kind="insert", ess=10.0, max_q=max_q, r_max=r_max,
              counts_impl="fused")

    rec = {"n": n, "m_global": m, "max_q": max_q, "r_max": r_max,
           "cpu_count": os.cpu_count(),
           "note": ("single-core container: mesh_round_us times the real "
                    "d-virtual-device psum program on one core (overhead "
                    "check); per_device_round_us times the m/d-row local "
                    "contraction each of d real chips would run — the "
                    "honest wall-time proxy at fixed global m"),
           "shards": {}}
    base_us = None
    for d in shard_counts:
        entry = {}
        if d <= len(jax.devices()):
            entry["mesh_round_us"] = round(_time(
                lambda a, _d=d: sweep(dj, aj, a, data_shards=_d, **kw),
                adjj, reps=reps), 1)
        # per-device work: the local shard's rows on ONE device, padded the
        # same way the mesh pads them (sentinel rows are exact no-ops)
        local = np.asarray(pad_data_rows(dj, r_max, d))[: -(-m // d)]
        lj = jnp.asarray(local)
        us = _time(lambda a, _l=lj: sweep(_l, aj, a, **kw), adjj, reps=reps)
        entry["m_local"] = int(local.shape[0])
        entry["per_device_round_us"] = round(us, 1)
        if d == 1:
            base_us = us
        entry["per_round_speedup"] = round(base_us / us, 2)
        rec["shards"][str(d)] = entry
    rec["per_round_speedup_at_d4"] = (
        rec["shards"]["4"]["per_round_speedup"] if "4" in rec["shards"]
        else None)
    return rec


def bench_family_cache(n: int = 120, m: int = 2000, k: int = 4,
                       seed: int = 0) -> dict:
    """Persistent family-score cache (core/score_cache) on an end-to-end
    cGES run: hit rate, score evaluations saved, per-round wall speedup,
    and the trajectory-identity check (cached adj/score must equal the
    uncached run bitwise — the cache's exact-key contract).
    """
    from repro.core import GESConfig, cges

    rng = np.random.default_rng(seed)
    arities = rng.integers(2, 4, size=n).astype(np.int32)
    data = np.stack([rng.integers(0, a, size=m) for a in arities],
                    1).astype(np.int32)
    base = dict(max_q=256, counts_impl="fused")
    r0 = cges(data, arities, k=k, limit=True,
              config=GESConfig(**base, family_cache=False))
    # Capacity sized to the run's working set: the uncached baseline's
    # host-dict ScoreCache is unbounded, so an under-provisioned device
    # cache would charge eviction-induced recomputes to the cache itself.
    r1 = cges(data, arities, k=k, limit=True,
              config=GESConfig(**base, family_cache=True,
                               cache_capacity=8192))
    st = r1.family_cache_stats or {}
    return {
        "n": n, "m": m, "k": k, "engine": "host",
        "hit_rate": round(st.get("hit_rate", 0.0), 4),
        "hits": st.get("hits", 0), "misses": st.get("misses", 0),
        # every hit is one whole column sweep (an O(m) contraction over
        # all candidates of that child) the engine did not run
        "column_sweeps_skipped": st.get("hits", 0),
        "evals_uncached": r0.n_score_evals,
        "evals_cached": r1.n_score_evals,
        "rounds": r1.rounds,
        "uncached_round_s": round(r0.wall_time_s / max(r0.rounds, 1), 3),
        "cached_round_s": round(r1.wall_time_s / max(r1.rounds, 1), 3),
        "per_round_speedup": round(
            (r0.wall_time_s / max(r0.rounds, 1))
            / (r1.wall_time_s / max(r1.rounds, 1)), 2),
        "trajectory_identical": bool(
            np.array_equal(r0.adj, r1.adj) and r0.score == r1.score),
    }


def bench_async_ring(n: int = 12, m: int = 800, k: int = 3,
                     max_rounds: int = 8, seed: int = 7) -> dict:
    """Async double-buffered ring (core/ring_async) vs the lockstep compiled
    ring on one seeded problem, plus the elastic kill-one-member drill.

    Both engines run warm (one throwaway run each eats compilation) on the
    SAME partition, and healthy async replays the lockstep trajectory
    exactly, so the comparison is pure per-round wall time.  Single-core
    honesty, same spirit as bench_data_sharded: the k threaded members
    share this one core, so the measured walls compare the two real
    programs' total per-round cost — the lockstep ring executes every
    member's GES inner loop inside ONE synchronized XLA program per round
    (plus the pmax barrier), while async members run their loops
    independently and receive the predecessor BN into the double-buffered
    mailbox WHILE sweeping.  The per-member phase rows are the k-host
    story: ``permute_wait_us`` is the blocked remainder of neighbor
    transfer (the part NOT hidden behind the sweep) and stays 2-3 orders
    under ``sweep_us``.  ``rounds_executed`` > committed rounds is the
    bounded speculation window — those sweeps are wasted only on one core;
    on k hosts they overlap the verdict lap.
    """
    from repro.core import GESConfig, partition
    from repro.core.ring import RingSpec, ring_cges
    from repro.core.ring_async import run_ring_async_threads
    from repro.data.bn import forward_sample, random_bn
    from repro.launch.mesh import make_host_mesh

    rng = np.random.default_rng(seed)
    bn = random_bn(rng, n=n, n_edges=int(1.3 * n), max_parents=2)
    data = forward_sample(bn, m, rng)
    cfg = GESConfig(max_q=256, counts_impl="fused")
    masks = partition.partition_edges(data, bn.arities, k)
    pid_j = jnp.asarray(partition.pid_tables(masks))

    # lockstep compiled ring, W-wide (the exact engine="jax" program)
    mesh = make_host_mesh(k)
    spec = RingSpec(k=k, max_rounds=max_rounds)
    ring_cges(data, bn.arities, masks, mesh, spec, cfg, pid_tables=pid_j)
    t0 = time.perf_counter()
    _, s_lock, r_lock = ring_cges(data, bn.arities, masks, mesh, spec, cfg,
                                  pid_tables=pid_j)
    lock_wall = time.perf_counter() - t0

    # async threaded ring (same run_member path the process launcher runs)
    kw = dict(config=cfg, max_rounds=max_rounds, wall_limit_s=600.0)
    run_ring_async_threads(data, bn.arities, masks, **kw)
    t0 = time.perf_counter()
    out = run_ring_async_threads(data, bn.arities, masks, **kw)
    async_wall = time.perf_counter() - t0

    surv = out["survivors"]
    r_exec = max(out["members"][i]["rounds_executed"] for i in surv)
    lock_round = lock_wall / max(r_lock, 1) * 1e6
    async_round = async_wall / max(out["rounds"], 1) * 1e6
    tot = {ph: sum(float(np.sum(out["members"][i]["timings"][ph]))
                   for i in surv)
           for ph in ("wait_us", "fuse_us", "sweep_us")}
    per_member = {
        str(i): {
            "permute_wait_us": round(float(np.sum(
                out["members"][i]["timings"]["wait_us"]))
                / out["members"][i]["rounds_executed"], 1),
            "fuse_us": round(float(np.sum(
                out["members"][i]["timings"]["fuse_us"]))
                / out["members"][i]["rounds_executed"], 1),
            "sweep_us": round(float(np.sum(
                out["members"][i]["timings"]["sweep_us"]))
                / out["members"][i]["rounds_executed"], 1),
        }
        for i in surv}

    rec = {
        "n": n, "m": m, "k": k, "max_rounds": max_rounds,
        "counts_impl": cfg.counts_impl, "max_q": cfg.max_q,
        "lockstep": {"round_us": round(lock_round, 1),
                     "rounds": int(r_lock),
                     "best_score": round(float(np.max(s_lock)), 3)},
        "async": {"round_us": round(async_round, 1),
                  "rounds": int(out["rounds"]),
                  "rounds_executed": int(r_exec),
                  "best_score": round(float(out["best_score"]), 3),
                  # blocked transfer wait vs sweep: the overlap evidence
                  "wait_fraction_of_sweep": round(
                      tot["wait_us"] / max(tot["sweep_us"], 1e-9), 4),
                  "phase_us_per_round": per_member},
        "round_speedup_vs_lockstep": round(lock_round / async_round, 2),
        "trajectory_match": bool(
            int(out["rounds"]) == int(r_lock)
            and abs(float(out["best_score"]) - float(np.max(s_lock)))
            <= 1e-2),
    }

    # elastic drill: member 1 goes silent after round 1; survivors fold its
    # E_1 into its ring predecessor, re-stitch, and converge with k-1
    kill = run_ring_async_threads(
        data, bn.arities, masks, config=cfg, max_rounds=max_rounds,
        die_member=1, die_after_round=1, hb_timeout_s=1.5,
        wall_limit_s=600.0)
    rec["elastic"] = {
        "die_member": 1, "die_after_round": 1,
        "survivors": kill["survivors"],
        "rounds": int(kill["rounds"]),
        "best_score": round(float(kill["best_score"]), 3),
        "converged": bool(not kill["timed_out"]
                          and np.isfinite(kill["best_score"])),
        "deaths_via": sorted({d["via"] for i in kill["survivors"]
                              for d in kill["members"][i]["deaths"]}),
    }
    return rec


def _repo_metadata() -> dict:
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, cwd=os.path.dirname(
                os.path.abspath(__file__))).stdout.strip() or None
    except OSError:
        commit = None
    return {"platform": jax.default_backend(),
            "jax_version": jax.__version__,
            "device_count": len(jax.devices()),
            "cpu_count": os.cpu_count(),
            "commit": commit,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep-json", default=None,
                    help="also run the fused-vs-loop sweep bench at paper "
                         "scale and write the record to this path")
    ap.add_argument("--sweep-n", type=int, default=400)
    ap.add_argument("--sweep-m", type=int, default=5000)
    args = ap.parse_args()
    for name, us, derived in bench_all():
        print(f"{name},{us:.0f},{derived}")
    if args.sweep_json:
        rec = bench_sweep(n=args.sweep_n, m=args.sweep_m)
        rec["meta"] = _repo_metadata()
        rec["data_sharded"] = bench_data_sharded(n=args.sweep_n,
                                                 m=args.sweep_m)
        rec["family_cache"] = bench_family_cache()
        rec["async_ring"] = bench_async_ring()
        with open(args.sweep_json, "w") as f:
            json.dump(rec, f, indent=2)
            f.write("\n")
        print(f"bdeu_sweep/loop,{rec['engines']['loop_segment']['sweep_us']:.0f},"
              f"n={rec['n']} m={rec['m']}")
        print(f"bdeu_sweep/fused,{rec['engines']['fused']['sweep_us']:.0f},"
              f"speedup={rec['speedup_fused_vs_loop']}x "
              f"dispatch_ratio={rec['dispatch_ratio']}x")
        d = rec["delete"]
        print(f"bdeu_sweep/delete_loop,"
              f"{d['engines']['loop_segment']['sweep_us']:.0f},"
              f"{rec['n']} table builds")
        print(f"bdeu_sweep/delete_fused,{d['engines']['fused']['sweep_us']:.0f},"
              f"speedup={d['speedup_fused_vs_loop']}x (1 table build)")
        dp = rec["delete_pallas"]
        print(f"bdeu_sweep/delete_pallas,{dp['vmem_resident_us']:.0f},"
              f"VMEM-resident column; two_step={dp['two_step_us']:.0f}us "
              f"speedup={dp['speedup_vmem_vs_two_step']}x "
              f"hbm_traffic_ratio={dp['hbm_bytes']['traffic_ratio']}x")
        s = rec["restricted"]
        print(f"bdeu_sweep/restricted_fused,"
              f"{s['engines']['fused']['sweep_us']:.0f},"
              f"W={s['W']} cost={s['fused_w_cost_fraction_of_full_n']}"
              f" of full-n fused")
        r = rec["ring_compiled"]
        print(f"bdeu_sweep/ring_compiled,{r['restricted_round_us']:.0f},"
              f"(W,n) pid_table round W={r['W']} "
              f"cost={r['w_cost_fraction_of_full_n']} of full-n round")
        fu = rec["fusion"]
        print(f"fusion/jit,{fu['jit_us']:.0f},"
              f"host={fu['host_us']:.0f}us "
              f"prerefactor={fu.get('legacy_jit_us', 0):.0f}us "
              f"speedup={fu.get('speedup_jit_vs_prerefactor', 0)}x "
              f"fusion/sweep_round={fu['fusion_over_sweep_round']}")
        ds = rec["data_sharded"]
        print(f"bdeu_sweep/data_sharded,"
              f"{ds['shards']['4']['per_device_round_us']:.0f},"
              f"per-device round at d=4 (m/d rows); "
              f"per_round_speedup_at_d4={ds['per_round_speedup_at_d4']}x "
              f"mesh_d4={ds['shards']['4'].get('mesh_round_us', 0):.0f}us")
        fc = rec["family_cache"]
        print(f"cges/family_cache,{fc['cached_round_s'] * 1e6:.0f},"
              f"hit_rate={fc['hit_rate']} "
              f"column_sweeps_skipped={fc['column_sweeps_skipped']} "
              f"per_round_speedup={fc['per_round_speedup']}x "
              f"identical={fc['trajectory_identical']}")
        ar = rec["async_ring"]
        print(f"ring_async/round,{ar['async']['round_us']:.0f},"
              f"lockstep={ar['lockstep']['round_us']:.0f}us "
              f"speedup={ar['round_speedup_vs_lockstep']}x "
              f"wait/sweep={ar['async']['wait_fraction_of_sweep']} "
              f"match={ar['trajectory_match']} "
              f"elastic_survivors={ar['elastic']['survivors']}")


if __name__ == "__main__":
    main()
