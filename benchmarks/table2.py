"""Paper Table 2 (a: BDeu, b: SMHD, c: time) — all 8 algorithm configs on
family-matched synthetic link/pigs/munin-like networks.

Full paper scale (n=724/441/1041, m=5000, 11 replicas) is a CPU-week on this
container; the default `--scale` keeps the *structure statistics* of each
family (edge/node ratio, arities, max parents) at a tractable n.  All
algorithm code paths are identical to full scale — n is just a config.

Reported per (family, algorithm): normalized BDeu (Table 2a), SMHD vs the
true structure (2b), wall seconds + score-evaluation count (2c; evals are the
machine-independent cost the paper's CPU-time column proxies).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _peek_data_shards(argv):
    for i, a in enumerate(argv):
        if a == "--data-shards" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--data-shards="):
            return int(a.split("=", 1)[1])
    return 1


# --data-shards d runs every sweep on a d-device data-axis mesh
# (core/sweeps): XLA_FLAGS must be set before the backend initializes,
# which importing repro.core below does — hence this pre-import argv peek.
_d = _peek_data_shards(sys.argv[1:])
if _d > 1 and "host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_d}").strip()

import numpy as np

from repro.core import GESConfig, ScoreCache, cges, fges_host, ges_host
from repro.core.dag import smhd_np
from repro.data.bn import benchmark_bn, forward_sample

ALGOS = ["fGES", "GES", "cGES-2", "cGES-4", "cGES-8",
         "cGES-L-2", "cGES-L-4", "cGES-L-8"]


def run_algo(name: str, data, arities, config) -> dict:
    t0 = time.perf_counter()
    if name == "GES":
        r = ges_host(data, arities, config=config, cache=ScoreCache())
        adj, score, evals = r.adj, r.score, r.n_score_evals
        extra = {}
    elif name == "fGES":
        r = fges_host(data, arities, config=config)
        adj, score, evals = r.adj, r.score, r.n_score_evals
        extra = {}
    else:
        k = int(name.split("-")[-1])
        limit = "-L-" in name
        r = cges(data, arities, k=k, limit=limit, config=config)
        adj, score, evals = r.adj, r.score, r.n_score_evals
        extra = {"rounds": r.rounds, "parallel_wall_s": r.parallel_wall_s}
    return dict(adj=adj, score=score, evals=evals,
                wall_s=time.perf_counter() - t0, **extra)


def bench(families, scale: float, m: int, seeds, algos=ALGOS, verbose=True,
          data_shards: int = 1):
    rows = []
    for fam in families:
        for seed in seeds:
            bn = benchmark_bn(fam, scale=scale, seed=seed)
            data = forward_sample(bn, m, np.random.default_rng(seed + 100))
            config = GESConfig(max_q=1024, data_shards=data_shards)
            for algo in algos:
                r = run_algo(algo, data, bn.arities, config)
                row = {
                    "family": fam, "seed": seed, "algo": algo, "n": bn.n,
                    "m": m,
                    "bdeu_per_inst": r["score"] / m,
                    "smhd": smhd_np(r["adj"], bn.adj),
                    "wall_s": round(r["wall_s"], 2),
                    # k-worker deployment wall (ring rounds concurrent);
                    # GES/fGES have no ring -> same as serial wall
                    "wall_par_s": round(r.get("parallel_wall_s",
                                              r["wall_s"]), 2),
                    "score_evals": r["evals"],
                }
                rows.append(row)
                if verbose:
                    print(f"  {fam:12s} seed{seed} {algo:9s} "
                          f"BDeu/м={row['bdeu_per_inst']:9.4f} "
                          f"SMHD={row['smhd']:4d} t={row['wall_s']:7.2f}s "
                          f"t_par={row['wall_par_s']:7.2f}s "
                          f"evals={row['score_evals']}")
    return rows


def summarize(rows):
    """Per (family, algo) means — the three sub-tables of Table 2."""
    import collections
    acc = collections.defaultdict(list)
    for r in rows:
        acc[(r["family"], r["algo"])].append(r)
    out = []
    for (fam, algo), rs in sorted(acc.items()):
        out.append({
            "family": fam, "algo": algo,
            "bdeu_per_inst": float(np.mean([r["bdeu_per_inst"] for r in rs])),
            "smhd": float(np.mean([r["smhd"] for r in rs])),
            "wall_s": float(np.mean([r["wall_s"] for r in rs])),
            "wall_par_s": float(np.mean([r["wall_par_s"] for r in rs])),
            "score_evals": float(np.mean([r["score_evals"] for r in rs])),
        })
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.055)
    ap.add_argument("--m", type=int, default=1500)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--families", nargs="+",
                    default=["pigs_like", "link_like", "munin_like"])
    ap.add_argument("--data-shards", type=int, default=1,
                    help="shard every sweep's instance axis over this many "
                         "(forced-host) devices with psum'd count tables — "
                         "table-identical results, per-device HBM traffic "
                         "and contraction flops scale by 1/d (see "
                         "repro.launch.roofline.sweep_data_axis_terms)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    rows = bench(args.families, args.scale, args.m, list(range(args.seeds)),
                 data_shards=args.data_shards)
    summary = summarize(rows)
    print("\n=== Table 2 summary (means over seeds) ===")
    print(f"{'family':12s} {'algo':9s} {'BDeu/m':>10s} {'SMHD':>7s} "
          f"{'time(s)':>8s} {'evals':>10s}")
    for s in summary:
        print(f"{s['family']:12s} {s['algo']:9s} {s['bdeu_per_inst']:10.4f} "
              f"{s['smhd']:7.1f} {s['wall_s']:8.2f} {s['score_evals']:10.0f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "summary": summary}, f, indent=1)
    return summary


if __name__ == "__main__":
    main()
