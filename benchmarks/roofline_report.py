"""Render §Dry-run / §Roofline tables from benchmarks/results/dryrun.jsonl."""
from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.launch.roofline import PEAK_FLOPS, roofline_terms  # noqa: E402


def load(path):
    recs = [json.loads(l) for l in open(path)]
    # keep the newest record per cell
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    return seen


FIX_HINTS = {
    ("memory_s", "train"): "fuse f32 intermediates / relax remat policy to cut HBM traffic",
    ("memory_s", "prefill"): "flash-style attention tiling keeps the KV working set in VMEM",
    ("memory_s", "decode"): "decode is cache-read-bound: shrink cache reads (GQA kv already minimal) or batch more requests",
    ("collective_s", "train"): "overlap DP gradient reduce-scatter with backward; int8 compression (training/compress.py)",
    ("collective_s", "prefill"): "re-shard activations once per layer boundary instead of per-op; prefer reduce-scatter over all-gather",
    ("collective_s", "decode"): "eliminate cache all-gathers: keep cache batch/sequence-sharded end-to-end through the update",
    ("compute_s", "train"): "already compute-bound: cut redundant (non-model) flops — remat recompute, MoE capacity slack",
    ("compute_s", "prefill"): "already compute-bound: reduce attention flops via kernel fusion",
    ("compute_s", "decode"): "compute-bound decode is unusual: check redundant per-token recompute",
}


def table(recs, mesh="pod1"):
    rows = []
    for (arch, shape, mk), r in sorted(recs.items()):
        if mk != mesh:
            continue
        if r.get("skipped"):
            rows.append((arch, shape, "SKIP", r["reason"], "", "", "", "", ""))
            continue
        if not r.get("ok") or "compute_s" not in r:
            rows.append((arch, shape, "FAIL/partial", r.get("error", "")[:40],
                         "", "", "", "", ""))
            continue
        # recompute fraction under the current (useful-flops) definition
        t = roofline_terms(r["flops_per_chip"], r["hbm_bytes_per_chip"],
                           r["collective_bytes_per_chip"],
                           useful_flops=r.get("model_flops_per_chip", 0.0))
        kind = ("train" if shape.startswith("train")
                else "prefill" if shape.startswith("prefill") else "decode")
        hint = FIX_HINTS.get((t["dominant"], kind), "")
        rows.append((arch, shape,
                     f"{t['compute_s']:.4g}", f"{t['memory_s']:.4g}",
                     f"{t['collective_s']:.4g}",
                     t["dominant"].replace("_s", ""),
                     f"{r.get('useful_flops_ratio', 0):.3f}",
                     f"{t['roofline_fraction']:.4f}", hint))
    return rows


def markdown(recs, mesh="pod1"):
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "useful/HLO flops | roofline frac | what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    for row in table(recs, mesh):
        out.append("| " + " | ".join(str(c) for c in row) + " |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="benchmarks/results/dryrun.jsonl")
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--format", default="md", choices=["md", "csv"])
    args = ap.parse_args()
    recs = load(args.path)
    if args.format == "md":
        print(markdown(recs, args.mesh))
    else:
        for row in table(recs, args.mesh):
            print(",".join(str(c) for c in row))


if __name__ == "__main__":
    main()
