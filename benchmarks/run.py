"""Benchmark entry point:  PYTHONPATH=src python -m benchmarks.run

One harness per paper artifact:
  * Table 2a/2b/2c  -> benchmarks.table2 (BDeu / SMHD / time+evals sweep)
  * dry-run + roofline -> benchmarks.roofline_report over results/dryrun.jsonl
  * kernels        -> benchmarks.kernel_bench (CSV: name,us_per_call,derived)

Env overrides: REPRO_BENCH_SCALE / REPRO_BENCH_M / REPRO_BENCH_SEEDS.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    scale = float(os.environ.get("REPRO_BENCH_SCALE", "0.05"))
    m = int(os.environ.get("REPRO_BENCH_M", "1200"))
    seeds = int(os.environ.get("REPRO_BENCH_SEEDS", "1"))

    print("=" * 72)
    print("## Paper Table 2 (BDeu / SMHD / CPU time) — reduced-scale families")
    print(f"## scale={scale} m={m} seeds={seeds} (env REPRO_BENCH_* to change)")
    print("=" * 72)
    from benchmarks import table2
    rows = table2.bench(["pigs_like", "link_like", "munin_like"],
                        scale, m, list(range(seeds)))
    summary = table2.summarize(rows)
    print("\n=== Table 2 summary ===")
    print(f"{'family':12s} {'algo':9s} {'BDeu/m':>10s} {'SMHD':>7s} "
          f"{'time(s)':>8s} {'evals':>10s}")
    for s in summary:
        print(f"{s['family']:12s} {s['algo']:9s} {s['bdeu_per_inst']:10.4f} "
              f"{s['smhd']:7.1f} {s['wall_s']:8.2f} {s['score_evals']:10.0f}")

    # paper's headline: cGES-L cheaper than GES at comparable quality
    for fam in ("pigs_like", "link_like", "munin_like"):
        ges = [s for s in summary if s["family"] == fam and s["algo"] == "GES"]
        cg4 = [s for s in summary
               if s["family"] == fam and s["algo"] == "cGES-L-4"]
        if ges and cg4:
            sp_t = ges[0]["wall_s"] / max(cg4[0]["wall_par_s"], 1e-9)
            sp_e = ges[0]["score_evals"] / max(cg4[0]["score_evals"], 1)
            dq = cg4[0]["bdeu_per_inst"] - ges[0]["bdeu_per_inst"]
            print(f"speedup {fam:12s} cGES-L-4 vs GES: k-worker wall x{sp_t:.2f}, "
                  f"score-evals x{sp_e:.2f}, dBDeu/m {dq:+.4f}")

    print()
    print("=" * 72)
    print("## Roofline (single-pod 16x16, from dry-run artifacts)")
    print("=" * 72)
    from benchmarks import roofline_report
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "results", "dryrun.jsonl")
    if os.path.exists(path):
        recs = roofline_report.load(path)
        for row in roofline_report.table(recs, "pod1"):
            print(",".join(str(c) for c in row[:8]))
    else:
        print("dryrun.jsonl missing — run benchmarks/sweep_dryrun.sh first")

    print()
    print("=" * 72)
    print("## Kernel microbenchmarks (name,us_per_call,derived)")
    print("=" * 72)
    from benchmarks import kernel_bench
    for name, us, derived in kernel_bench.bench_all():
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
