"""BN fusion (sigma-consistent edge union) invariants + unified-engine
equivalence: the host and traceable engines in core/fusion.py must agree
adjacency-for-adjacency (same GHO ranks, same lowest-index tie-breaks, same
covered-reversal sequence), and the refactor onto maintained depths /
incremental GHO costs must be output-identical to the pre-refactor code
(pinned hashes + seeded ring trajectories)."""
import hashlib
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import dag, fusion
# compat imports: pre-unification callers got the traceable engine from ring
from repro.core.ring import fuse_jit, gho_order_jit, sigma_consistent_jit


def _rand(seed, n=7):
    rng = np.random.default_rng(seed)
    return dag.random_dag_np(rng, n, rng.integers(3, 2 * n), max_parents=3)


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_sigma_consistent_is_sigma_dag(seed):
    adj = _rand(seed)
    n = adj.shape[0]
    rng = np.random.default_rng(seed + 1)
    sigma = rng.permutation(n)
    out = fusion.sigma_consistent(adj, sigma)
    rank = np.empty(n, dtype=int)
    rank[sigma] = np.arange(n)
    xs, ys = np.nonzero(out)
    assert np.all(rank[xs] < rank[ys])          # respects sigma => DAG
    assert dag.is_dag_np(out)


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_sigma_consistent_preserves_skeleton(seed):
    """Transform only adds edges / reverses: original skeleton survives."""
    adj = _rand(seed)
    n = adj.shape[0]
    sigma = np.random.default_rng(seed + 1).permutation(n)
    out = fusion.sigma_consistent(adj, sigma)
    sk_in = adj | adj.T
    sk_out = out | out.T
    assert np.all(sk_out[sk_in])                # superset of skeleton


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_fuse_is_dag_and_contains_skeletons(seed):
    a, b = _rand(seed), _rand(seed + 13)
    f = fusion.fuse([a, b])
    assert dag.is_dag_np(f)
    sk = (a | a.T) | (b | b.T)
    assert np.all((f | f.T)[sk])


def test_fusion_edge_union_empty_cases():
    a = _rand(5)
    zeros = np.zeros_like(a)
    for engine in fusion.FUSION_ENGINES:
        assert np.array_equal(
            fusion.fusion_edge_union(zeros, a, engine=engine), a.astype(bool))
        assert np.array_equal(
            fusion.fusion_edge_union(a, zeros, engine=engine), a.astype(bool))
        assert not fusion.fusion_edge_union(zeros, zeros, engine=engine).any()


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_fuse_jit_matches_invariants(seed):
    """Device-side fusion: result must be a DAG containing both skeletons."""
    a, b = _rand(seed), _rand(seed + 29)
    f = np.asarray(fuse_jit(jnp.asarray(a.astype(np.int8)),
                            jnp.asarray(b.astype(np.int8))))
    assert dag.is_dag_np(f.astype(bool))
    sk = (a | a.T) | (b | b.T)
    assert np.all((f.astype(bool) | f.astype(bool).T)[sk])


def test_gho_order_jit_is_permutation():
    a, b = _rand(3), _rand(4)
    rank = np.asarray(gho_order_jit(jnp.asarray(a.astype(np.int8)),
                                    jnp.asarray(b.astype(np.int8))))
    assert sorted(rank.tolist()) == list(range(a.shape[0]))


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_sigma_consistent_jit_matches_host(seed):
    adj = _rand(seed)
    n = adj.shape[0]
    sigma = np.random.default_rng(seed + 1).permutation(n)
    rank = np.empty(n, dtype=np.int32)
    rank[sigma] = np.arange(n)
    host = fusion.sigma_consistent(adj, sigma)
    dev = np.asarray(sigma_consistent_jit(
        jnp.asarray(adj.astype(np.int8)), jnp.asarray(rank)))
    assert np.array_equal(host, dev.astype(bool))


# ---------------------------------------------------------------------------
# Unified-engine equivalence (tentpole): host == jit, adjacency-for-adjacency
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=12, deadline=None)
def test_fuse_host_vs_jit_engines(seed):
    """fuse(engine="jit") must equal fuse(engine="host") exactly, on mixed
    sizes and input counts — including all-empty and one-empty stacks."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 14))
    j = int(rng.integers(2, 4))
    adjs = [dag.random_dag_np(rng, n, int(rng.integers(0, 2 * n)),
                              max_parents=3) for _ in range(j)]
    if seed % 3 == 1:
        adjs[0] = np.zeros_like(adjs[0])        # one empty input
    if seed % 5 == 2:
        adjs = [np.zeros_like(a) for a in adjs]  # all empty
    f_host = fusion.fuse(adjs, engine="host")
    f_jit = fusion.fuse(adjs, engine="jit")
    assert np.array_equal(f_host, f_jit)
    # pairwise path (the ring's operator) with the Algorithm-1 empty guard
    f_eu_h = fusion.fusion_edge_union(adjs[0], adjs[1], engine="host")
    f_eu_j = fusion.fusion_edge_union(adjs[0], adjs[1], engine="jit")
    assert np.array_equal(f_eu_h, f_eu_j)
    f_tr = np.asarray(fusion.fuse_trace(jnp.asarray(adjs[0].astype(np.int8)),
                                        jnp.asarray(adjs[1].astype(np.int8))))
    assert np.array_equal(f_eu_h, f_tr.astype(bool))


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_fuse_fixed_sigma_host_vs_jit(seed):
    """Engine equality also under a caller-supplied ordering."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 12))
    adjs = [dag.random_dag_np(rng, n, int(rng.integers(1, 2 * n)),
                              max_parents=3) for _ in range(2)]
    sigma = rng.permutation(n)
    assert np.array_equal(fusion.fuse(adjs, sigma=sigma, engine="host"),
                          fusion.fuse(adjs, sigma=sigma, engine="jit"))


def test_fuse_pinned_outputs():
    """The maintained-depth / incremental-cost engines are output-identical
    to the pre-refactor implementation: hashes captured from the PR 3 code
    on seeded random DAG stacks."""
    pins = [
        ((0, 6, 2),
         "25f38ab0f0ca2152e789795b58f7464e5da7350aa5ccaa581efeaf80cf8abbca"),
        ((1, 9, 2),
         "694bcb293cadaad165a4ce2248d979c3e91fde84c7b559232fc8966a3758a007"),
        ((2, 13, 2),
         "68fd0ad275fca2a42b53cbd2c2c024986ad9365582cd12b6875ade0d9cd51f44"),
        ((4, 11, 3),
         "c658e59d58581342b96e941bd4cbe65f5d862b014876f3ff87685e2c536e0147"),
    ]
    for (seed, n, j), want in pins:
        rng = np.random.default_rng(seed)
        adjs = [dag.random_dag_np(rng, n, rng.integers(n // 2, 2 * n),
                                  max_parents=3) for _ in range(j)]
        for engine in fusion.FUSION_ENGINES:
            f = fusion.fuse(adjs, engine=engine)
            got = hashlib.sha256(
                np.ascontiguousarray(f.astype(np.uint8)).tobytes()).hexdigest()
            assert got == want, (engine, seed, n, j)


def test_gho_order_incremental_identity():
    """The incremental cost update (subtract the sunk node's stacked column)
    reproduces the re-summing implementation order-for-order — including tie
    cases, which must break to the lowest node index."""

    def gho_resum(adjs):                 # pre-refactor reference, re-sums
        n = adjs[0].shape[0]             # all k (n, n) masks per position
        remaining = np.ones(n, dtype=bool)
        order = np.empty(n, dtype=np.int64)
        stack = [a.astype(bool) for a in adjs]
        for pos in range(n - 1, -1, -1):
            costs = np.full(n, np.inf)
            idx = np.flatnonzero(remaining)
            sub_cost = np.zeros(n, dtype=np.int64)
            for a in stack:
                sub_cost += (a & remaining[None, :]).sum(axis=1)
            costs[idx] = sub_cost[idx]
            v = int(np.argmin(costs))
            order[pos] = v
            remaining[v] = False
        return order

    n = 9
    zeros = np.zeros((n, n), dtype=bool)
    chain = np.zeros((n, n), dtype=bool)
    for i in range(n - 1):
        chain[i, i + 1] = True
    cases = [
        [zeros, zeros],                       # total tie: lowest index wins
        [chain, chain],                       # duplicated input
        [chain, chain.T.copy()],              # symmetric costs => ties
    ]
    for seed in range(6):
        r = np.random.default_rng(seed)
        m = int(r.integers(2, 14))
        cases.append([dag.random_dag_np(r, m, int(r.integers(0, 2 * m)),
                                        max_parents=3)
                      for _ in range(int(r.integers(1, 4)))])
    for adjs in cases:
        want = gho_resum(adjs)
        got = fusion.gho_order(adjs)
        assert np.array_equal(got, want), (len(adjs), adjs[0].shape)
        # jit rank is the inverse permutation of the same order
        rank = np.asarray(fusion.gho_rank_trace(
            jnp.asarray(np.stack(adjs).astype(np.int8))))
        assert np.array_equal(rank[want], np.arange(adjs[0].shape[0]))
    assert np.array_equal(fusion.gho_order([zeros, zeros]),
                          np.arange(n)[::-1])  # explicit tie-break pin


def test_depth_maintenance_matches_scratch_oracle():
    """The maintained depth vector equals the from-scratch longest-path
    layer at every subgraph size (the invariant the transforms rely on)."""
    rng = np.random.default_rng(23)
    adj = dag.random_dag_np(rng, 10, 18, max_parents=3)
    in_s = np.ones(10, dtype=bool)
    depth = fusion._settle_depth_np(adj, in_s, np.zeros(10, dtype=np.int64))
    assert np.array_equal(depth, fusion._subgraph_depth(adj, in_s))
    for v in rng.permutation(10)[:6]:
        # drop sinks the way sigma_consistent does: recompute oracle fresh
        in_s[v] = False
        depth = fusion._settle_depth_np(adj, in_s,
                                        np.where(in_s, depth, -1))
        assert np.array_equal(depth, fusion._subgraph_depth(adj, in_s))


# ---------------------------------------------------------------------------
# Engine knob plumbing (REPRO_FUSION_ENGINE / fusion_engine=)
# ---------------------------------------------------------------------------

def test_fusion_engine_validation(monkeypatch):
    with pytest.raises(ValueError, match="unknown fusion engine"):
        fusion.check_fusion_engine("bogus")
    with pytest.raises(ValueError, match="unknown fusion engine"):
        fusion.fuse([_rand(0), _rand(1)], engine="numpy")
    monkeypatch.setenv("REPRO_FUSION_ENGINE", "jti")   # typo'd env fails loud
    with pytest.raises(ValueError, match="unknown fusion engine"):
        fusion.resolve_fusion_engine(None)
    monkeypatch.setenv("REPRO_FUSION_ENGINE", "jit")
    assert fusion.resolve_fusion_engine(None) == "jit"
    monkeypatch.delenv("REPRO_FUSION_ENGINE", raising=False)
    assert fusion.resolve_fusion_engine(None) == "host"
    assert fusion.resolve_fusion_engine("host") == "host"


def test_cges_fusion_engine_knob(monkeypatch):
    """cges() resolves fusion_engine from the env (mirroring
    REPRO_COUNTS_IMPL), errors loudly on unknown values BEFORE learning, and
    both engines drive the host round loop to the same adjacency."""
    from repro.core import GESConfig
    from repro.core.cges import cges
    from repro.data.bn import forward_sample, random_bn

    rng = np.random.default_rng(6)
    bn = random_bn(rng, n=7, n_edges=8, max_parents=2)
    data = forward_sample(bn, 250, rng)
    cfg = GESConfig(max_q=64)

    monkeypatch.setenv("REPRO_FUSION_ENGINE", "wat")
    with pytest.raises(ValueError, match="unknown fusion engine"):
        cges(data, bn.arities, k=2, config=cfg, max_rounds=1)
    monkeypatch.delenv("REPRO_FUSION_ENGINE")
    with pytest.raises(ValueError, match="unknown fusion engine"):
        cges(data, bn.arities, k=2, config=cfg, max_rounds=1,
             fusion_engine="trace")

    res = {eng: cges(data, bn.arities, k=2, config=cfg, max_rounds=3,
                     fusion_engine=eng) for eng in fusion.FUSION_ENGINES}
    assert np.array_equal(res["host"].adj, res["jit"].adj)
    assert np.isclose(res["host"].score, res["jit"].score, rtol=1e-9)
    assert res["host"].rounds == res["jit"].rounds


# ---------------------------------------------------------------------------
# Ring-trajectory regression across the refactor
# ---------------------------------------------------------------------------

def test_ring_cges_trajectory_pinned():
    """Seeded ring_cges trajectories on k in {1, 2} meshes are UNCHANGED
    across the fusion refactor: adjacency hashes + round counts captured
    from the pre-refactor (PR 3) code.  Subprocess: needs a multi-device
    host platform."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, "src")
        import hashlib
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import GESConfig, partition
        from repro.core.cges import edge_add_limit
        from repro.core.ring import RingSpec, ring_cges
        from repro.data.bn import forward_sample, random_bn

        PINS = {  # k -> (sha256 of uint8 graphs, rounds, edge count)
            1: ("adc9b65734b1424900c93fae59e090679a11be620f4c12b1"
                "2c98cd71d1cf794e", 2, 21),
            2: ("6ab7ffaa2d8a1e2be7a1ed3d6d2a9126eeefbdd016627504"
                "e12c43751d956c81", 3, 51),
        }
        rng = np.random.default_rng(3)
        bn = random_bn(rng, n=12, n_edges=16, max_parents=2)
        data = forward_sample(bn, 600, rng)
        for k, (want, want_rounds, want_edges) in PINS.items():
            masks = partition.partition_edges(data, bn.arities, k)
            mesh = Mesh(np.array(jax.devices()[:k]), ("ring",))
            spec = RingSpec(k=k, max_rounds=6)
            cfg = GESConfig(max_q=64, counts_impl="segment")
            g, s, r = ring_cges(data, bn.arities, masks, mesh, spec, cfg,
                                add_limit=edge_add_limit(bn.n, k))
            got = hashlib.sha256(np.ascontiguousarray(
                g.astype(np.uint8)).tobytes()).hexdigest()
            assert r == want_rounds, (k, r)
            assert int(g.sum()) == want_edges, (k, int(g.sum()))
            assert got == want, (k, got)
        print("RING_PINNED_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "RING_PINNED_OK" in r.stdout, r.stderr[-3000:]


# ---------------------------------------------------------------------------
# Paper-scale benchmark (slow: deselected in CI)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fusion_bench_n400():
    """The n=400 jit fusion step must beat the pre-refactor
    per-reversal-depth-recompute baseline (the BENCH_sweep.json claim)."""
    bench_dir = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks")
    sys.path.insert(0, bench_dir)
    try:
        from kernel_bench import bench_fusion
    finally:
        sys.path.remove(bench_dir)
    rec = bench_fusion(n=400, reps=1)
    assert rec["speedup_jit_vs_prerefactor"] > 1.0, rec
