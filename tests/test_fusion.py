"""BN fusion (sigma-consistent edge union) invariants."""
import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import dag, fusion
from repro.core.ring import fuse_jit, gho_order_jit, sigma_consistent_jit


def _rand(seed, n=7):
    rng = np.random.default_rng(seed)
    return dag.random_dag_np(rng, n, rng.integers(3, 2 * n), max_parents=3)


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_sigma_consistent_is_sigma_dag(seed):
    adj = _rand(seed)
    n = adj.shape[0]
    rng = np.random.default_rng(seed + 1)
    sigma = rng.permutation(n)
    out = fusion.sigma_consistent(adj, sigma)
    rank = np.empty(n, dtype=int)
    rank[sigma] = np.arange(n)
    xs, ys = np.nonzero(out)
    assert np.all(rank[xs] < rank[ys])          # respects sigma => DAG
    assert dag.is_dag_np(out)


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_sigma_consistent_preserves_skeleton(seed):
    """Transform only adds edges / reverses: original skeleton survives."""
    adj = _rand(seed)
    n = adj.shape[0]
    sigma = np.random.default_rng(seed + 1).permutation(n)
    out = fusion.sigma_consistent(adj, sigma)
    sk_in = adj | adj.T
    sk_out = out | out.T
    assert np.all(sk_out[sk_in])                # superset of skeleton


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_fuse_is_dag_and_contains_skeletons(seed):
    a, b = _rand(seed), _rand(seed + 13)
    f = fusion.fuse([a, b])
    assert dag.is_dag_np(f)
    sk = (a | a.T) | (b | b.T)
    assert np.all((f | f.T)[sk])


def test_fusion_edge_union_empty_cases():
    a = _rand(5)
    zeros = np.zeros_like(a)
    assert np.array_equal(fusion.fusion_edge_union(zeros, a), a.astype(bool))
    assert np.array_equal(fusion.fusion_edge_union(a, zeros), a.astype(bool))


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_fuse_jit_matches_invariants(seed):
    """Device-side fusion: result must be a DAG containing both skeletons."""
    a, b = _rand(seed), _rand(seed + 29)
    f = np.asarray(fuse_jit(jnp.asarray(a.astype(np.int8)),
                            jnp.asarray(b.astype(np.int8))))
    assert dag.is_dag_np(f.astype(bool))
    sk = (a | a.T) | (b | b.T)
    assert np.all((f.astype(bool) | f.astype(bool).T)[sk])


def test_gho_order_jit_is_permutation():
    a, b = _rand(3), _rand(4)
    rank = np.asarray(gho_order_jit(jnp.asarray(a.astype(np.int8)),
                                    jnp.asarray(b.astype(np.int8))))
    assert sorted(rank.tolist()) == list(range(a.shape[0]))


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_sigma_consistent_jit_matches_host(seed):
    adj = _rand(seed)
    n = adj.shape[0]
    sigma = np.random.default_rng(seed + 1).permutation(n)
    rank = np.empty(n, dtype=np.int32)
    rank[sigma] = np.arange(n)
    host = fusion.sigma_consistent(adj, sigma)
    dev = np.asarray(sigma_consistent_jit(
        jnp.asarray(adj.astype(np.int8)), jnp.asarray(rank)))
    assert np.array_equal(host, dev.astype(bool))
