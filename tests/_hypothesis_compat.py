"""Deterministic fallback for ``hypothesis`` (not installed in this image).

Test modules import ``given / settings / st`` from here.  When the real
hypothesis package is available it is used verbatim; otherwise a minimal
deterministic shim replays each property test over a fixed sample sequence:
the strategy bounds first (lo, hi — the classic edge cases), then seeded
pseudo-random draws.  The sequence depends only on the example index, so runs
are reproducible and failures are re-runnable without shrinking machinery.

Only ``st.integers`` is shimmed — the only strategy this suite uses.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_MAX_EXAMPLES = 10  # cap: the shim has no shrinking, keep it quick

    class _IntegersStrategy:
        def __init__(self, min_value: int, max_value: int):
            self.lo = int(min_value)
            self.hi = int(max_value)

        def example_at(self, i: int, rng) -> int:
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return int(rng.integers(self.lo, self.hi + 1))

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> "_IntegersStrategy":
            return _IntegersStrategy(min_value, max_value)

    def settings(max_examples: int = 10, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            n_ex = min(getattr(fn, "_shim_max_examples", 10),
                       _FALLBACK_MAX_EXAMPLES)

            # NB: zero-arg wrapper on purpose (and no functools.wraps):
            # pytest must not see the strategy parameters as fixtures.
            def wrapper():
                for i in range(n_ex):
                    rng = np.random.default_rng(0xBDE0 + 7919 * i)
                    fn(*(s.example_at(i, rng) for s in strategies))

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
