"""Per-arch smoke tests (reduced same-family configs) + semantic equivalences:
padded heads == unpadded, chunked attention == dense, prefill == step decode.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.training import build_train_step, init_opt_state


def _batch(cfg, key, B=2, T=16):
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, T), 0, cfg.vocab)}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    B, T = 2, 16
    batch = _batch(cfg, key, B, T)
    logits, aux = transformer.forward(
        cfg, params, batch["tokens"], frames=batch.get("frames"),
        patch_embeds=batch.get("patch_embeds"))
    assert logits.shape == (B, T, cfg.vocab_pad)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    step = jax.jit(build_train_step(cfg))
    p2, o2, metrics = step(params, init_opt_state(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = sum(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = transformer.init_params(key, cfg)
    B, S = 2, 32
    cache = transformer.init_cache(cfg, B, S)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    logits, cache2 = transformer.decode_step(cfg, params, cache, tok,
                                             jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_pad)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # cache structure preserved
    assert set(cache2.keys()) == set(cache.keys())


def _dense_cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=32, vocab=64, n_heads=4,
                n_kv_heads=2, head_dim=8, d_ff=64, act="swiglu",
                tie_embeddings=True, remat=False, param_dtype="float32",
                compute_dtype="float32", attn_impl="dense")
    base.update(kw)
    return ModelConfig(**base)


def test_padded_heads_exact():
    """n_heads_pad with zero-masked slots must compute the true arch exactly."""
    cfg = _dense_cfg()
    cfg_pad = dataclasses.replace(cfg, n_heads_pad=8)
    key = jax.random.PRNGKey(3)
    p = transformer.init_params(key, cfg)
    p_pad = transformer.init_params(key, cfg_pad)
    tok = jax.random.randint(key, (2, 12), 0, cfg.vocab)
    # same per-head weights in the first slots is not guaranteed by RNG, so
    # build p_pad from p by explicit PER-KV-GROUP zero padding (the layout
    # init_attention uses): kv=2 groups of 2 real heads each -> 4 slots each.
    def pad_heads(a, name):
        if name == "wq":     # (L, d, 4, hd) -> (L, d, 2, 2, hd) -> pad group
            L, d, h, hd = a.shape
            g = a.reshape(L, d, 2, 2, hd)
            g = jnp.pad(g, ((0, 0), (0, 0), (0, 0), (0, 2), (0, 0)))
            return g.reshape(L, d, 8, hd)
        if name == "wo":     # (L, 4, hd, d)
            L, h, hd, d = a.shape
            g = a.reshape(L, 2, 2, hd, d)
            g = jnp.pad(g, ((0, 0), (0, 0), (0, 2), (0, 0), (0, 0)))
            return g.reshape(L, 8, hd, d)
        return a
    lp = dict(p["layers"])
    attn = dict(lp["attn"])
    attn["wq"] = pad_heads(attn["wq"], "wq")
    attn["wo"] = pad_heads(attn["wo"], "wo")
    lp["attn"] = attn
    p_pad = dict(p, layers=lp)
    out, _ = transformer.forward(cfg, p, tok)
    out_pad, _ = transformer.forward(cfg_pad, p_pad, tok)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_pad),
                               rtol=1e-5, atol=1e-5)


def test_chunked_attention_matches_dense():
    cfg_d = _dense_cfg(attn_impl="dense")
    cfg_c = _dense_cfg(attn_impl="chunked", attn_chunk=8)
    key = jax.random.PRNGKey(5)
    p = transformer.init_params(key, cfg_d)
    tok = jax.random.randint(key, (2, 32), 0, cfg_d.vocab)
    out_d, _ = transformer.forward(cfg_d, p, tok)
    out_c, _ = transformer.forward(cfg_c, p, tok)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_c),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["gemma_7b", "mamba2_130m", "zamba2_7b"])
def test_prefill_matches_stepwise_decode(arch):
    """logits from full forward at position t == t-th step of decode loop."""
    cfg = dataclasses.replace(get_smoke_config(arch),
                              param_dtype="float32",
                              compute_dtype="float32")
    key = jax.random.PRNGKey(7)
    params = transformer.init_params(key, cfg)
    B, T = 1, 8
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    full, _ = transformer.forward(cfg, params, tokens)

    cache = transformer.init_cache(cfg, B, T)
    outs = []
    for t in range(T):
        lg, cache = transformer.decode_step(
            cfg, params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(step_logits, np.float32),
        rtol=2e-3, atol=2e-3)


def test_moe_padded_experts_never_selected():
    from repro.models.config import MoEConfig
    cfg = _dense_cfg(moe=MoEConfig(n_experts=3, top_k=2, n_experts_pad=4))
    key = jax.random.PRNGKey(9)
    params = transformer.init_params(key, cfg)
    tok = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    out, aux = transformer.forward(cfg, params, tok)
    assert not bool(jnp.isnan(out).any())
    # router mask: padded expert gets zero combined weight by construction;
    # validated indirectly: aux loss finite and output finite
    assert np.isfinite(float(aux))


def test_param_count_matches_tree():
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        tree_n = sum(int(np.prod(l.shape))
                     for l in jax.tree.leaves(params))
        # analytic count excludes norm scales and the frontend stub; allow 5%
        analytic = cfg.param_count()
        pad_overhead = (cfg.vocab_pad - cfg.vocab) * cfg.d_model
        assert abs(tree_n - analytic) / tree_n < 0.30, (arch, tree_n, analytic)
