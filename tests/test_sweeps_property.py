"""Property-style tests for the unified sweep engine (via the deterministic
hypothesis shim in _hypothesis_compat): randomized arities, graphs and pid
subsets — including W > degree self-padding and empty E_i columns — must
yield entry-for-entry identical masked insert/delete columns and (W, n)
matrices under every backend, and identical to the host BDeu oracle."""
import numpy as np
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.core import bdeu
from repro.core.partition import pid_table_from_allowed
from repro.core.sweeps import sweep

IMPLS = ("segment", "fused", "fused_pallas")


def _random_case(seed):
    """Random mixed-arity data + random DAG + random allowed mask."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 9))
    m = int(rng.integers(60, 200))
    arities = rng.integers(2, 5, size=n)
    data = np.stack([rng.integers(0, a, size=m) for a in arities], 1)
    # random DAG: edges only from lower to higher position in a random order
    order = rng.permutation(n)
    adj = np.zeros((n, n), dtype=np.int8)
    for j in range(1, n):
        y = order[j]
        k = int(rng.integers(0, min(3, j) + 1))
        for x in rng.choice(order[:j], size=k, replace=False):
            adj[x, y] = 1
    allowed = rng.random((n, n)) < rng.uniform(0.2, 0.8)
    np.fill_diagonal(allowed, False)
    if n > 4:
        allowed[:, int(rng.integers(0, n))] = False    # empty E_i column
    return rng, n, arities, data, adj, allowed


def _jnp(data, arities):
    return (jnp.asarray(data.astype(np.int32)),
            jnp.asarray(arities.astype(np.int32)))


def _agree(a, b, ctx):
    assert a.shape == b.shape, ctx
    assert np.array_equal(np.isneginf(a), np.isneginf(b)), ctx
    assert np.array_equal(np.isposinf(a), np.isposinf(b)), ctx
    assert np.array_equal(np.isnan(a), np.isnan(b)), ctx
    f = np.isfinite(a)
    assert np.allclose(a[f], b[f], rtol=1e-4, atol=2e-3), ctx


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=6, deadline=None)
def test_property_restricted_columns_agree(seed):
    """Random pid subsets (self-pads included): every backend returns the
    same masked (W,) insert/delete column, matching the host oracle."""
    rng, n, arities, data, adj, _ = _random_case(seed)
    dj, aj = _jnp(data, arities)
    y = int(rng.integers(0, n))
    W = int(rng.integers(1, n + 1))
    n_real = int(rng.integers(0, W)) if W > 1 else 0
    real = rng.choice(n, size=n_real, replace=False)
    pids = np.full(W, y, dtype=np.int32)           # W > degree: self-padded
    pids[:real.size] = real
    kw = dict(y=y, pids=jnp.asarray(pids), ess=10.0, max_q=256,
              r_max=int(arities.max()))
    pm = adj[:, y].astype(bool)
    base = bdeu.local_score_np(data, arities, y, list(np.flatnonzero(pm)))
    for kind in ("insert", "delete"):
        cols = {impl: np.asarray(sweep(dj, aj, jnp.asarray(adj), kind=kind,
                                       counts_impl=impl, **kw))
                for impl in IMPLS}
        for impl in IMPLS[1:]:
            _agree(cols["segment"], cols[impl], (seed, kind, impl))
        # host-oracle check at every legal entry
        for w, x in enumerate(pids):
            legal = (x != y) and (not pm[x] if kind == "insert" else pm[x])
            if not legal:
                assert np.isneginf(cols["segment"][w]), (seed, kind, w)
                continue
            new_pa = (list(np.flatnonzero(pm)) + [x] if kind == "insert"
                      else [p for p in np.flatnonzero(pm) if p != x])
            q = int(np.prod(arities[new_pa])) if new_pa else 1
            if q > 256:
                continue                            # max_q-guarded entry
            want = bdeu.local_score_np(data, arities, y, new_pa) - base
            assert np.isclose(cols["segment"][w], want,
                              rtol=1e-4, atol=2e-3), (seed, kind, w)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=5, deadline=None)
def test_property_restricted_matrices_agree(seed):
    """Random allowed masks (empty columns included) and W >= max degree:
    every backend returns the same masked (W, n) matrix, equal to the full
    (n, n) loop matrix gathered through the pid table."""
    rng, n, arities, data, adj, allowed = _random_case(seed)
    dj, aj = _jnp(data, arities)
    # sometimes force extra self-padding (W wider than any column occupancy)
    extra = int(rng.integers(0, 3))
    occ = max(1, int(allowed.sum(axis=0).max()))
    tbl = pid_table_from_allowed(allowed, width=min(n, occ + extra))
    W = tbl.shape[1]
    kw = dict(ess=10.0, max_q=256, r_max=int(arities.max()))
    for kind in ("insert", "delete"):
        D_full = np.asarray(sweep(dj, aj, jnp.asarray(adj), kind=kind,
                                  counts_impl="segment", **kw))
        mats = {impl: np.asarray(sweep(dj, aj, jnp.asarray(adj), kind=kind,
                                       counts_impl=impl,
                                       pid_table=jnp.asarray(tbl), **kw))
                for impl in IMPLS}
        for impl in IMPLS[1:]:
            _agree(mats["segment"], mats[impl], (seed, kind, impl))
        got = mats["segment"]
        assert got.shape == (W, n)
        for y in range(n):
            for w in range(W):
                x = tbl[y, w]
                if x == y:
                    assert np.isneginf(got[w, y]), (seed, kind, y, w)
                else:
                    a, b = got[w, y], D_full[x, y]
                    if np.isfinite(b):
                        assert np.isclose(a, b, rtol=1e-4, atol=2e-3), \
                            (seed, kind, y, w)
                    else:
                        assert np.isneginf(a) == np.isneginf(b) and \
                            np.isposinf(a) == np.isposinf(b), \
                            (seed, kind, y, w)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=4, deadline=None)
def test_property_pid_table_ges_jit_trajectory(seed):
    """Random restricted masks: the compiled W-wide ges_jit program takes
    the identical greedy trajectory as the full-n-masked program and the
    host driver."""
    from repro.core import GESConfig, ges_host, ges_jit

    rng, n, arities, data, _, allowed = _random_case(seed)
    dj, aj = _jnp(data, arities)
    tbl = jnp.asarray(pid_table_from_allowed(allowed))
    cfg = GESConfig(max_q=64, counts_impl="fused")
    zeros = jnp.zeros((n, n), jnp.int8)
    mask_j = jnp.asarray(allowed.astype(np.int8))
    a_full, s_full, *_ = ges_jit(dj, aj, zeros, mask_j, config=cfg)
    a_res, s_res, *_ = ges_jit(dj, aj, zeros, mask_j, config=cfg,
                               pid_table=tbl)
    assert np.array_equal(np.asarray(a_full), np.asarray(a_res)), seed
    assert np.isclose(float(s_full), float(s_res), rtol=1e-6), seed
    res_h = ges_host(data, arities, allowed=allowed, config=cfg)
    assert np.array_equal(res_h.adj, np.asarray(a_res)), seed


def test_pid_tables_degenerate_shapes():
    """n in {0, 1} and all-empty E_i masks build well-defined self-pad /
    zero-width tables instead of raising (the shapes a trivial partition or
    an empty edge subset hands the ring)."""
    from repro.core.partition import pid_tables

    # n = 0: nothing to sweep — (k, 0, 0) tables
    assert pid_table_from_allowed(np.zeros((0, 0), bool)).shape == (0, 0)
    assert pid_tables(np.zeros((2, 0, 0), bool)).shape == (2, 0, 0)
    # n = 1: the only slot is the self-pad
    t1 = pid_table_from_allowed(np.zeros((1, 1), bool))
    assert t1.shape == (1, 1) and t1[0, 0] == 0
    k1 = pid_tables(np.ones((3, 1, 1), bool))       # self-loop cleared
    assert k1.shape == (3, 1, 1) and (k1 == 0).all()
    # all-empty masks at n > 1: every slot self-pads its own column
    n = 5
    t = pid_table_from_allowed(np.zeros((n, n), bool))
    assert t.shape == (n, 1)
    assert np.array_equal(t[:, 0], np.arange(n))
    ks = pid_tables(np.zeros((2, n, n), bool))
    assert ks.shape == (2, n, 1)
    # explicit zero width is allowed when nothing is occupied
    assert pid_table_from_allowed(np.zeros((n, n), bool), width=0).shape == \
        (n, 0)
    # but a width below the real occupancy still fails loudly
    allowed = np.zeros((n, n), bool)
    allowed[[1, 2], 0] = True
    try:
        pid_table_from_allowed(allowed, width=1)
    except ValueError:
        pass
    else:
        raise AssertionError("width < occupancy must raise")


def test_empty_pid_table_sweep_is_all_masked():
    """A degenerate all-self-pad pid table flows through the sweep engine:
    the (1, n) restricted matrix is -inf everywhere (nothing toggleable)."""
    rng = np.random.default_rng(0)
    n, m = 4, 50
    arities = rng.integers(2, 4, size=n)
    data = np.stack([rng.integers(0, a, size=m) for a in arities], 1)
    tbl = pid_table_from_allowed(np.zeros((n, n), bool))
    dj, aj = _jnp(data, arities)
    for kind in ("insert", "delete"):
        for impl in IMPLS:
            D = np.asarray(sweep(dj, aj, jnp.zeros((n, n), jnp.int8),
                                 kind=kind, pid_table=jnp.asarray(tbl),
                                 ess=10.0, max_q=64,
                                 r_max=int(arities.max()),
                                 counts_impl=impl))
            assert D.shape == (1, n)
            assert np.all(np.isneginf(D)), (kind, impl)
