"""Slot-pool serving engine: continuous batching semantics."""
import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import ServeEngine
from repro.models import transformer


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("qwen2_7b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_slots_fill_and_free(engine):
    cfg, params = engine
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=32)
    rng = np.random.default_rng(0)
    s0 = eng.submit(rng.integers(0, cfg.vocab, 5).astype(np.int32))
    s1 = eng.submit(rng.integers(0, cfg.vocab, 5).astype(np.int32))
    assert {s0, s1} == {0, 1}
    assert eng.submit(np.zeros(3, np.int32)) is None   # pool full
    eng.free(s0)
    assert eng.submit(np.zeros(3, np.int32)) == s0     # slot reused


def test_interleaved_decoding_matches_solo(engine):
    """A request decoded alongside another must produce the same tokens as
    the same request decoded alone (slot isolation)."""
    cfg, params = engine
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 6).astype(np.int32)

    def run(with_neighbor):
        eng = ServeEngine(cfg, params, n_slots=2, max_seq=32)
        s = eng.submit(prompt)
        if with_neighbor:
            eng.submit(rng.integers(0, cfg.vocab, 4).astype(np.int32))
        last = np.zeros(2, np.int32)
        # seed the slot's first decode input with its last prompt token
        last[s] = prompt[-1]
        outs = []
        for _ in range(6):
            nxt = eng.step_all(last)
            outs.append(int(nxt[s]))
            last = nxt
        return outs

    solo = run(False)
    pair = run(True)
    assert solo == pair


def test_positions_advance_per_slot(engine):
    cfg, params = engine
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=32)
    eng.submit(np.zeros(4, np.int32))
    assert eng.pos[0] == 4 and eng.pos[1] == 0
    eng.step_all(np.zeros(2, np.int32))
    assert eng.pos[0] == 5 and eng.pos[1] == 0   # empty slot never advances
