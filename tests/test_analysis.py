"""Tests for repro.analysis — the three-pass static-analysis gate.

Layout mirrors the passes:

* lint fixtures — one true-positive AND one known-clean (FP-free) snippet
  per rule, plus suppression-comment semantics and the live-repo zero pin;
* trace contracts — unit checks of the jaxpr walkers on hand-built
  programs, then ONE full ``run_contract_checks()`` (module-scoped; it
  compiles the real programs) asserting zero findings, one-psum count
  paths and the zero-re-trace steady-state pin;
* VMEM budgets — repo defaults fit, genuinely over-budget configurations
  are rejected with a per-term breakdown;
* the RING_ASYNC_DEBUG regression — env set AFTER import is honoured;
* CLI — exit 0 on clean input, nonzero on a seeded violation, JSON shape.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import lint_paths, lint_source
from repro.analysis.findings import Finding, Report
from repro.analysis.vmem import (DEFAULT_BUDGET, DEFAULT_CONFIGS,
                                 check_config, footprint, run_vmem_checks)

REPO_SRC = Path(__file__).resolve().parents[1] / "src"


def rules_of(findings):
    return sorted(f.rule for f in findings)


def lint_snippet(source, path="src/repro/core/fake.py", rules=None):
    from repro.analysis.lint import RULES
    return lint_source(textwrap.dedent(source), path,
                       rules if rules is not None else RULES)


# ---------------------------------------------------------------------------
# Pass 1 — lint fixtures
# ---------------------------------------------------------------------------

class TestR001ImportTimeEnv:
    def test_module_level_get_flagged(self):
        fs = lint_snippet("""
            import os
            DEBUG = bool(int(os.environ.get("RING_ASYNC_DEBUG", "0")))
        """)
        assert rules_of(fs) == ["R001"]
        assert "RING_ASYNC_DEBUG" in fs[0].message

    def test_getenv_and_subscript_flagged(self):
        fs = lint_snippet("""
            import os
            A = os.getenv("REPRO_COUNTS_IMPL")
            B = os.environ["RING_PORT"]
        """)
        assert rules_of(fs) == ["R001", "R001"]

    def test_def_time_contexts_flagged(self):
        # decorator args and parameter defaults evaluate at import time
        fs = lint_snippet("""
            import os
            def f(impl=os.environ.get("REPRO_COUNTS_IMPL", "segment")):
                return impl
        """)
        assert rules_of(fs) == ["R001"]

    def test_function_body_read_clean(self):
        fs = lint_snippet("""
            import os
            def debug_enabled():
                return os.environ.get("RING_ASYNC_DEBUG", "0") == "1"
        """)
        assert fs == []

    def test_default_factory_lambda_clean(self):
        fs = lint_snippet("""
            import os
            import dataclasses
            @dataclasses.dataclass
            class Cfg:
                impl: str = dataclasses.field(
                    default_factory=lambda: os.environ.get(
                        "REPRO_COUNTS_IMPL", "segment"))
        """)
        assert fs == []

    def test_non_repo_names_and_writes_clean(self):
        # XLA_FLAGS mutation and non-REPRO_/RING_ reads are launch/ idiom
        fs = lint_snippet("""
            import os
            FLAGS = os.environ.get("XLA_FLAGS", "")
            os.environ["XLA_FLAGS"] = FLAGS + " --xla_foo"
        """)
        assert fs == []


class TestR002BareAssert:
    def test_assert_on_parameter_flagged(self):
        fs = lint_snippet("""
            def sweep(m, tile_m=256):
                assert m % tile_m == 0, (m, tile_m)
        """, path="src/repro/kernels/fake/fake.py")
        assert rules_of(fs) == ["R002"]
        assert "python -O" in fs[0].message

    def test_assert_on_shape_unpacked_names_flagged(self):
        # hq/hkv are not parameters but derive from q/k — taint propagates
        fs = lint_snippet("""
            def attn(q, k):
                b, hq, t, d = q.shape
                _, hkv, s, _ = k.shape
                assert hq % hkv == 0, (hq, hkv)
        """, path="src/repro/kernels/fake/fake.py")
        assert rules_of(fs) == ["R002"]

    def test_valueerror_pattern_clean(self):
        fs = lint_snippet("""
            def sweep(m, tile_m=256):
                if m % tile_m != 0:
                    raise ValueError(f"m={m} not a multiple of {tile_m}")
        """, path="src/repro/kernels/fake/fake.py")
        assert fs == []

    def test_assert_on_internal_constant_clean(self):
        fs = lint_snippet("""
            def f(x):
                table_size = 128
                assert table_size % 2 == 0
                return x
        """, path="src/repro/core/fake.py")
        assert fs == []

    def test_outside_target_packages_clean(self):
        src = """
            def sweep(m, tile_m=256):
                assert m % tile_m == 0
        """
        assert lint_snippet(src, path="src/repro/launch/driver.py") == []
        assert lint_snippet(src, path="tests/test_fake.py") == []


class TestR003ClassBodyEnvDefault:
    def test_dataclass_default_flagged(self):
        # the exact pre-PR 5 GESConfig bug shape
        fs = lint_snippet("""
            import os
            import dataclasses
            @dataclasses.dataclass
            class Cfg:
                impl: str = os.environ.get("REPRO_COUNTS_IMPL", "segment")
        """)
        assert rules_of(fs) == ["R003"]
        assert "default_factory" in fs[0].message

    def test_plain_class_attribute_flagged(self):
        fs = lint_snippet("""
            import os
            class Cfg:
                port = int(os.environ.get("RING_PORT", "9000"))
        """)
        assert rules_of(fs) == ["R003"]

    def test_default_factory_clean(self):
        fs = lint_snippet("""
            import os
            import dataclasses
            @dataclasses.dataclass
            class Cfg:
                impl: str = dataclasses.field(
                    default_factory=lambda: os.environ.get(
                        "REPRO_COUNTS_IMPL", "segment"))
        """)
        assert fs == []


class TestR004SilentDispatch:
    def test_chain_without_else_flagged(self):
        fs = lint_snippet("""
            def run(engine, x):
                if engine == "host":
                    return x
                elif engine == "fast":
                    return x * 2
        """)
        assert rules_of(fs) == ["R004"]
        assert "no else" in fs[0].message

    def test_chain_with_silent_else_flagged(self):
        fs = lint_snippet("""
            def run(counts_impl, x):
                if counts_impl == "segment":
                    return x
                elif counts_impl == "onehot":
                    return x * 2
                else:
                    return x * 3
        """)
        assert rules_of(fs) == ["R004"]
        assert "silent else" in fs[0].message

    def test_chain_with_raising_else_clean(self):
        fs = lint_snippet("""
            def run(engine, x):
                if engine == "host":
                    return x
                elif engine == "jax":
                    return x * 2
                else:
                    raise ValueError(f"unknown engine {engine!r}")
        """)
        assert fs == []

    def test_validated_scope_clean(self):
        # bdeu.py idiom: an up-front check_*/resolve_* call legalises chains
        fs = lint_snippet("""
            def run(impl, x):
                impl = resolve_impl(impl)
                if impl == "segment":
                    return x
                elif impl == "onehot":
                    return x * 2
        """)
        assert fs == []

    def test_single_branch_and_compound_conditions_clean(self):
        fs = lint_snippet("""
            def run(engine, x, fast):
                if engine == "host":
                    x = x + 1
                if engine == "jax" and fast:
                    return x
                elif engine == "host" and not fast:
                    return x * 2
                return x
        """)
        assert fs == []


class TestSuppression:
    def test_same_line_and_line_above(self):
        fs = lint_snippet("""
            import os
            A = os.environ.get("REPRO_X")  # repro: allow=R001
            # repro: allow=R001
            B = os.environ.get("REPRO_Y")
            C = os.environ.get("REPRO_Z")
        """)
        assert len(fs) == 1 and "REPRO_Z" in fs[0].message

    def test_allow_all_and_wrong_id(self):
        # NB a suppression also covers the line directly below it, so the
        # two fixtures are separated to keep allow=all from leaking onto B
        fs = lint_snippet("""
            import os
            A = os.environ.get("REPRO_X")  # repro: allow=all

            B = os.environ.get("REPRO_Y")  # repro: allow=R002
        """)
        assert len(fs) == 1 and "REPRO_Y" in fs[0].message

    def test_syntax_error_reported_not_raised(self):
        fs = lint_source("def broken(:\n", "src/repro/core/x.py")
        assert rules_of(fs) == ["R000"]


def test_live_repo_lint_clean():
    """The gate this PR establishes: zero findings across src/."""
    findings = lint_paths([str(REPO_SRC)])
    assert findings == [], "\n".join(f.format() for f in findings)


# ---------------------------------------------------------------------------
# Pass 2 — trace-contract walkers (unit) + the full suite (module-scoped)
# ---------------------------------------------------------------------------

class TestJaxprWalkers:
    def test_psum_counting_and_axis_check(self):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.analysis.contracts import (check_collective_axes,
                                              count_psums)
        from repro.core.sweeps import shard_map_compat

        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        mapped = shard_map_compat(
            lambda x: jax.lax.psum(x.sum(), "data"),
            mesh, (P("data"),), P())
        jaxpr = jax.make_jaxpr(mapped)(jnp.ones((4,), jnp.float32))
        assert count_psums(jaxpr, "data") == 1
        assert count_psums(jaxpr, "ring") == 0
        assert check_collective_axes(jaxpr, {"data"}, "t") == []
        bad = check_collective_axes(jaxpr, {"ring"}, "t")
        assert rules_of(bad) == ["C001"]

    def test_while_carry_and_dtype_checks_clean_program(self):
        import jax
        import jax.numpy as jnp
        from repro.analysis.contracts import (check_dtypes,
                                              check_while_carries)

        def prog(x):
            return jax.lax.while_loop(
                lambda c: c[0] < 5,
                lambda c: (c[0] + 1, c[1] * jnp.float32(2.0)),
                (jnp.int32(0), x))

        jaxpr = jax.make_jaxpr(prog)(jnp.float32(1.0))
        assert check_while_carries(jaxpr, "t") == []
        assert check_dtypes(jaxpr, "t") == []

    def test_dtype_check_catches_float64(self):
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        from repro.analysis.contracts import check_dtypes

        with enable_x64():
            jaxpr = jax.make_jaxpr(lambda x: x * 2.0)(
                jnp.asarray(1.0, jnp.float64))
        fs = check_dtypes(jaxpr, "t")
        assert fs and all(f.rule == "C003" for f in fs)


@pytest.fixture(scope="module")
def contract_report():
    """ONE full contracts run (compiles the real programs, ~1 min)."""
    from repro.analysis.contracts import run_contract_checks
    return run_contract_checks()


class TestLiveContracts:
    def test_zero_findings(self, contract_report):
        findings, _ = contract_report
        assert findings == [], "\n".join(f.format() for f in findings)

    def test_every_count_path_has_exactly_one_psum(self, contract_report):
        _, info = contract_report
        paths = info["count_paths"]
        # all three single backends + both fused backends x insert/delete
        assert set(paths) == {
            "local_score[segment]", "local_score[onehot]",
            "local_score[pallas]",
            "insert_scores[fused]", "insert_scores[fused_pallas]",
            "delete_scores[fused]", "delete_scores[fused_pallas]",
        }
        assert all(v == 1 for v in paths.values()), paths

    def test_zero_steady_state_retraces(self, contract_report):
        """Regression pin: 3 same-shape rounds of the jitted ring /
        ges_jit / sweep programs must not grow a compilation cache."""
        _, info = contract_report
        assert info["retrace"] == {"ring": 0, "ges_jit": 0, "sweep": 0}

    def test_real_programs_were_traced(self, contract_report):
        _, info = contract_report
        programs = set(info["programs"])
        assert {"ges_jit_body", "ges_jit_body[restricted]",
                "ges_jit_body[cached]", "fuse_trace",
                "score_cache.lookup_or_compute"} <= programs
        assert any(p.startswith("ring[") for p in programs)
        assert any(p.startswith("sweep[") for p in programs)


# ---------------------------------------------------------------------------
# Pass 3 — VMEM budgets
# ---------------------------------------------------------------------------

class TestVmemBudgets:
    def test_repo_defaults_fit(self):
        findings, info = run_vmem_checks()
        assert findings == [], "\n".join(f.format() for f in findings)
        assert set(info["kernels"]) == set(DEFAULT_CONFIGS)

    def test_over_budget_flash_attention_rejected(self):
        # (2048, 2048) f32 logits + probs alone = 32 MiB > the 16 MiB core
        bad = check_config("flash_attention", block_q=2048, block_k=2048,
                           head_dim=128)
        assert bad is not None and bad.rule == "V001"
        assert "logits" in bad.message

    def test_over_budget_delete_sweep_rejected(self):
        # tile_m = 2048 makes the (tile_m, max_q) one-hot slab 32 MiB
        bad = check_config("bdeu_delete", max_q=4096, r_pad=128,
                           tile_m=2048, k_pad=1152, n_slots=11)
        assert bad is not None and bad.rule == "V001"

    def test_budget_monotone_in_tiles(self):
        small = footprint("bdeu_sweep", max_q=4096, r_max=8,
                          tile_m=128, tile_n=16).total_bytes
        big = footprint("bdeu_sweep", max_q=4096, r_max=8,
                        tile_m=512, tile_n=64).total_bytes
        assert small < big

    def test_custom_budget_and_unknown_kernel(self):
        findings, _ = run_vmem_checks(budget=1024)   # 1 KiB: everything fails
        assert len(findings) == len(DEFAULT_CONFIGS)
        with pytest.raises(ValueError, match="unknown kernel"):
            footprint("nope")


# ---------------------------------------------------------------------------
# Satellite regression — RING_ASYNC_DEBUG read at call time
# ---------------------------------------------------------------------------

class TestRingAsyncDebugEnv:
    def test_env_set_after_import_is_honoured(self, monkeypatch, capsys):
        from repro.core import ring_async   # imported with the var unset
        monkeypatch.delenv("RING_ASYNC_DEBUG", raising=False)
        assert ring_async._debug_enabled() is False
        ring_async._dbg("quiet")
        assert capsys.readouterr().out == ""
        # setting AFTER import must flip it on — the pre-PR import-time
        # binding froze False here forever
        monkeypatch.setenv("RING_ASYNC_DEBUG", "1")
        assert ring_async._debug_enabled() is True
        ring_async._dbg("loud")
        assert "loud" in capsys.readouterr().out
        monkeypatch.setenv("RING_ASYNC_DEBUG", "0")
        assert ring_async._debug_enabled() is False


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

class TestCLI:
    def _run(self, *argv):
        from repro.analysis.__main__ import main
        return main(list(argv))

    def test_clean_repo_exits_zero(self, capsys):
        rc = self._run("--skip-contracts", str(REPO_SRC))
        out = capsys.readouterr().out
        assert rc == 0
        assert "0 finding(s)" in out

    def test_seeded_violation_exits_nonzero(self, tmp_path, capsys):
        bad = tmp_path / "core" / "bad.py"
        bad.parent.mkdir()
        bad.write_text(textwrap.dedent("""
            import os
            MODE = os.environ.get("REPRO_MODE", "fast")
            def f(m, tile=8):
                assert m % tile == 0
        """))
        rc = self._run("--skip-contracts", "--skip-vmem", str(tmp_path))
        out = capsys.readouterr().out
        assert rc == 1
        assert "R001" in out and "R002" in out

    def test_json_report_shape(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text("import os\nX = os.getenv('RING_X')\n")
        rc = self._run("--skip-contracts", "--skip-vmem", "--json",
                       str(bad))
        report = json.loads(capsys.readouterr().out)
        assert rc == 1
        assert report["ok"] is False
        assert [f["rule"] for f in report["findings"]] == ["R001"]
        assert report["passes_run"] == ["lint"]

    def test_rule_subset_flag(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text("import os\nX = os.getenv('RING_X')\n")
        rc = self._run("--skip-contracts", "--skip-vmem",
                       "--rules", "R004", str(bad))
        capsys.readouterr()
        assert rc == 0          # R001 finding masked by the subset

    def test_vmem_budget_flag(self, capsys):
        rc = self._run("--skip-contracts", "--skip-lint",
                       "--vmem-budget", "1024")
        out = capsys.readouterr().out
        assert rc == 1 and "V001" in out

    @pytest.mark.slow
    def test_module_entrypoint_subprocess(self):
        """`python -m repro.analysis` end to end (lint+vmem; contracts are
        exercised in-process by the module fixture above)."""
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--skip-contracts",
             "--json", str(REPO_SRC)],
            capture_output=True, text=True,
            env={"PYTHONPATH": str(REPO_SRC), "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": "cpu"})
        assert proc.returncode == 0, proc.stderr
        report = json.loads(proc.stdout)
        assert report["ok"] is True
        assert set(report["passes_run"]) == {"lint", "vmem"}


# ---------------------------------------------------------------------------
# Findings / Report plumbing
# ---------------------------------------------------------------------------

def test_report_roundtrip():
    r = Report()
    assert r.ok
    r.extend([Finding("R001", "x.py", 3, "msg", "X = 1")])
    r.passes_run.append("lint")
    assert not r.ok
    data = json.loads(r.to_json())
    assert data["findings"][0]["line"] == 3
    assert "R001" in Finding("R001", "x.py", 3, "msg").format()
