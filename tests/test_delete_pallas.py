"""VMEM-resident Pallas delete sweep (kernels/bdeu_sweep.delete_scores):
kernel == jnp oracle == loop/segment engines over random arities, padded
r_max, empty parent sets, the max_q +/-inf guard and restricted-W pids —
through both the column and full-matrix sweep entry points — plus a seeded
ring_cges trajectory pin under counts_impl="fused_pallas"."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import bdeu
from repro.core.sweeps import sweep


def _jnp(data, arities):
    return (jnp.asarray(data.astype(np.int32)),
            jnp.asarray(arities.astype(np.int32)))


def _random_case(seed, n_lo=4, n_hi=10):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(n_lo, n_hi))
    m = int(rng.integers(80, 300))
    arities = rng.integers(2, 5, size=n)
    data = np.stack([rng.integers(0, a, size=m) for a in arities], 1)
    order = rng.permutation(n)
    adj = np.zeros((n, n), dtype=np.int8)
    for j in range(1, n):
        y = order[j]
        k = int(rng.integers(0, min(3, j) + 1))
        for x in rng.choice(order[:j], size=k, replace=False):
            adj[x, y] = 1
    return rng, n, arities, data, adj


# ---------------------------------------------------------------------------
# Kernel-level: Pallas (interpret) vs the jnp oracle, exact contract
# ---------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=6, deadline=None)
def test_delete_kernel_matches_ref_oracle(seed):
    """delete_scores (Pallas interpret) == delete_scores_ref (segment-sum
    oracle) on random families, including identity-padded slots and
    m/candidate padding."""
    from repro.kernels.bdeu_sweep import delete_scores

    rng, n, arities, data, _ = _random_case(seed)
    max_q = 64
    pa = rng.choice(n, size=min(3, n), replace=False)
    pm = np.zeros(n, dtype=bool)
    pm[pa] = True
    dj, aj = _jnp(data, arities)
    cfg, q0 = bdeu._slot_encode(dj, aj, jnp.asarray(pm))
    cfgc = jnp.clip(cfg, 0, max_q - 1)
    child = int(rng.integers(0, n))
    child_col = dj[:, child]

    slot_ar = np.where(pm, arities, 1).astype(np.int32)
    low = np.concatenate(
        [np.cumprod(slot_ar[::-1])[::-1][1:], np.ones(1, np.int32)]
    ).astype(np.int32)
    n_slots = 4
    ids = np.sort(pa)[:n_slots]
    ar_s = np.ones(n_slots, np.int32)
    low_s = np.ones(n_slots, np.int32)
    ar_s[:ids.size] = slot_ar[ids]
    low_s[:ids.size] = low[ids]
    qr = np.zeros(n_slots + 2, np.float32)
    qr[0] = float(q0)
    qr[1:n_slots + 1] = float(q0) / ar_s
    qr[n_slots + 1] = float(arities[child])
    cand_slot = np.zeros(n, np.int32)
    cand_slot[ids] = 1 + np.arange(ids.size)

    kw = dict(ess=10.0, max_q=max_q, r_max=int(arities.max()))
    got = np.asarray(delete_scores(
        cfgc, child_col, jnp.asarray(cand_slot), jnp.asarray(ar_s),
        jnp.asarray(low_s), jnp.asarray(qr), **kw))
    want = np.asarray(delete_scores(
        cfgc, child_col, jnp.asarray(cand_slot), jnp.asarray(ar_s),
        jnp.asarray(low_s), jnp.asarray(qr), use_ref=True, **kw))
    assert got.shape == want.shape == (n,)
    assert np.allclose(got, want, rtol=1e-5, atol=1e-4), seed
    # per-family host oracle at each real deletion
    base = bdeu.local_score_np(data, arities, child, list(np.sort(pa)))
    assert np.allclose(got[cand_slot == 0], base, rtol=1e-4, atol=2e-3)
    for x in ids:
        ref = bdeu.local_score_np(
            data, arities, child, [p for p in np.sort(pa) if p != x])
        assert np.isclose(got[x], ref, rtol=1e-4, atol=2e-3), (seed, x)


# ---------------------------------------------------------------------------
# Engine-level: fused_pallas delete columns/matrices vs the loop engine
# ---------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=6, deadline=None)
def test_delete_pallas_columns_match_loop(seed):
    """Random arities/graphs: the VMEM-resident fused_pallas delete column
    agrees with the loop engine entry-for-entry (masking included), and with
    the host oracle at every legal entry."""
    rng, n, arities, data, adj = _random_case(seed)
    dj, aj = _jnp(data, arities)
    y = int(rng.integers(0, n))
    kw = dict(kind="delete", y=y, ess=10.0, max_q=256,
              r_max=int(arities.max()))
    col_loop = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                                counts_impl="segment", **kw))
    col_pal = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                               counts_impl="fused_pallas", **kw))
    assert np.array_equal(np.isneginf(col_loop), np.isneginf(col_pal)), seed
    f = np.isfinite(col_loop)
    assert np.allclose(col_loop[f], col_pal[f], rtol=1e-4, atol=2e-3), seed
    pm = adj[:, y].astype(bool)
    base = bdeu.local_score_np(data, arities, y, list(np.flatnonzero(pm)))
    for x in np.flatnonzero(pm):
        want = bdeu.local_score_np(
            data, arities, y,
            [p for p in np.flatnonzero(pm) if p != x]) - base
        assert np.isclose(col_pal[x], want, rtol=1e-4, atol=2e-3), (seed, x)


@pytest.mark.parametrize("max_q", [300, 384, 512])
def test_delete_pallas_nonmultiple_max_q_chunking(max_q):
    """max_q above the 256-row chunk — including values 256 does NOT divide
    (300, 384) — must marginalize correctly: the final chunk is shifted back
    in bounds and its overlap rows masked, so every row scatters exactly
    once (vs the loop engine, which never chunks)."""
    rng, n, arities, data, adj = _random_case(23)
    dj, aj = _jnp(data, arities)
    y = int(np.flatnonzero(adj.sum(axis=0))[0])        # a child with parents
    kw = dict(kind="delete", y=y, ess=10.0, max_q=max_q,
              r_max=int(arities.max()))
    col_loop = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                                counts_impl="segment", **kw))
    col_pal = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                               counts_impl="fused_pallas", **kw))
    assert np.array_equal(np.isneginf(col_loop), np.isneginf(col_pal))
    f = np.isfinite(col_loop)
    assert f.any()
    assert np.allclose(col_loop[f], col_pal[f], rtol=1e-4, atol=2e-3)


def test_delete_pallas_empty_parent_set():
    """Empty Pa: the whole fused_pallas column is -inf (no legal deletes),
    no NaNs — the all-identity-slot path through the kernel."""
    rng, n, arities, data, _ = _random_case(3)
    adj = np.zeros((n, n), dtype=np.int8)
    dj, aj = _jnp(data, arities)
    col = np.asarray(sweep(dj, aj, jnp.asarray(adj), kind="delete", y=1,
                           ess=10.0, max_q=64, r_max=int(arities.max()),
                           counts_impl="fused_pallas"))
    assert np.all(np.isneginf(col))
    assert not np.isnan(col).any()


def test_delete_pallas_max_q_guard():
    """The +/-inf guard conventions of the kernel path equal the loop
    engine's exactly, including families whose own q0 overflows max_q
    (finite entries become +inf deltas, doubly-overflowing ones NaN)."""
    data = np.stack([np.random.default_rng(0).integers(0, a, size=400)
                     for a in (3, 4, 4, 2, 2)], 1)
    arities = np.array([3, 4, 4, 2, 2])
    n = arities.size
    adj = np.zeros((n, n), dtype=np.int8)
    adj[[0, 1, 2], 4] = 1                        # q0 = 48
    dj, aj = _jnp(data, arities)
    for max_q in (24, 12):                       # both overflow q0 = 48
        kw = dict(kind="delete", y=4, ess=10.0, max_q=max_q,
                  r_max=int(arities.max()))
        col_loop = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                                    counts_impl="segment", **kw))
        col_pal = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                                   counts_impl="fused_pallas", **kw))
        assert np.array_equal(np.isposinf(col_loop), np.isposinf(col_pal))
        assert np.array_equal(np.isneginf(col_loop), np.isneginf(col_pal))
        assert np.array_equal(np.isnan(col_loop), np.isnan(col_pal))


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=4, deadline=None)
def test_delete_pallas_restricted_pids(seed):
    """Restricted (W,) columns (ring E_i subsets incl. self-pads) under
    fused_pallas == loop engine, and the (W, n) pid_table matrix entry
    point routes through the same kernel."""
    from repro.core.partition import pid_table_from_allowed

    rng, n, arities, data, adj = _random_case(seed)
    dj, aj = _jnp(data, arities)
    y = int(rng.integers(0, n))
    W = int(rng.integers(1, n + 1))
    pids = np.full(W, y, dtype=np.int32)
    real = rng.choice(n, size=int(rng.integers(0, W)), replace=False)
    pids[:real.size] = real
    kw = dict(kind="delete", ess=10.0, max_q=256, r_max=int(arities.max()))
    col_loop = np.asarray(sweep(dj, aj, jnp.asarray(adj), y=y,
                                pids=jnp.asarray(pids),
                                counts_impl="segment", **kw))
    col_pal = np.asarray(sweep(dj, aj, jnp.asarray(adj), y=y,
                               pids=jnp.asarray(pids),
                               counts_impl="fused_pallas", **kw))
    assert col_pal.shape == (W,)
    assert np.array_equal(np.isneginf(col_loop), np.isneginf(col_pal)), seed
    f = np.isfinite(col_loop)
    assert np.allclose(col_loop[f], col_pal[f], rtol=1e-4, atol=2e-3), seed

    allowed = rng.random((n, n)) < 0.5
    np.fill_diagonal(allowed, False)
    tbl = pid_table_from_allowed(allowed)
    D_loop = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                              pid_table=jnp.asarray(tbl),
                              counts_impl="segment", **kw))
    D_pal = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                             pid_table=jnp.asarray(tbl),
                             counts_impl="fused_pallas", **kw))
    assert np.array_equal(np.isneginf(D_loop), np.isneginf(D_pal)), seed
    f = np.isfinite(D_loop)
    assert np.allclose(D_loop[f], D_pal[f], rtol=1e-4, atol=2e-3), seed


def test_delete_pallas_full_matrix_entry_point():
    """The full (n, n) BES initialization matrix under fused_pallas (the
    vmapped kernel path of bdeu._deltas_impl) == loop engine everywhere."""
    rng, n, arities, data, adj = _random_case(17)
    dj, aj = _jnp(data, arities)
    kw = dict(kind="delete", ess=10.0, max_q=256, r_max=int(arities.max()))
    D_loop = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                              counts_impl="segment", **kw))
    D_pal = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                             counts_impl="fused_pallas", **kw))
    assert np.array_equal(np.isneginf(D_loop), np.isneginf(D_pal))
    f = np.isfinite(D_loop)
    assert np.allclose(D_loop[f], D_pal[f], rtol=1e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# End-to-end: seeded ring trajectory pin under fused_pallas
# ---------------------------------------------------------------------------

def test_ring_cges_fused_pallas_trajectory_pin():
    """Seeded ring_cges on k in {1, 2} meshes: the compiled restricted ring
    under counts_impl="fused_pallas" (every BES delete column through the
    VMEM-resident kernel) is trajectory-identical to the segment engine —
    same best graphs, same scores, same round count (subprocess: needs a
    multi-device host platform)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, "src")
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import GESConfig, partition
        from repro.core.ring import RingSpec, ring_cges
        from repro.data.bn import forward_sample, random_bn

        rng = np.random.default_rng(7)
        bn = random_bn(rng, n=8, n_edges=9, max_parents=2)
        data = forward_sample(bn, 400, rng)
        for k in (1, 2):
            masks = partition.partition_edges(data, bn.arities, k)
            mesh = Mesh(np.array(jax.devices()[:k]), ("ring",))
            spec = RingSpec(k=k, max_rounds=3)
            out = {}
            for impl in ("segment", "fused_pallas"):
                cfg = GESConfig(max_q=64, counts_impl=impl)
                out[impl] = ring_cges(data, bn.arities, masks, mesh, spec,
                                      cfg, restricted=True)
            gS, sS, rS = out["segment"]
            gP, sP, rP = out["fused_pallas"]
            assert np.array_equal(gS, gP), (k, "adjacency drift")
            assert np.allclose(sS, sP, rtol=1e-5), (k, "score drift")
            assert rS == rP, (k, "round-count drift")
            assert gP.any()          # the ring actually learned something
        print("PALLAS_RING_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "PALLAS_RING_OK" in r.stdout, r.stderr[-3000:]
