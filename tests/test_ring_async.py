"""The asynchronous double-buffered elastic ring (core/ring_async.py).

Fast tier: frame/mailbox transport units, the threaded k-member ring pinned
against the lockstep host oracle (healthy EXACT parity — speculative rounds
never diverge because fuse/GES inputs don't depend on verdicts), the
elastic kill-one-member path, and ``cges(engine="async")``.

Slow tier (the dedicated CI leg runs these): the REAL multi-process
launcher — 2 OS processes forming a ``jax.distributed`` cluster with
seeded async-vs-lockstep score parity, and a 3-process kill-one-member
drill (``os._exit(13)`` mid-run, jax.distributed OFF — its coordination
service terminates surviving processes when a peer dies, which is exactly
why the data plane is our own sockets; see the module docstring).
"""
import json
import os
import socket
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.core import GESConfig, fusion, ges_host, partition
from repro.core.ring_async import (Mailbox, recv_frame, run_ring_async_threads,
                                   send_frame)
from repro.data.bn import forward_sample, random_bn

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Transport units
# ---------------------------------------------------------------------------

def test_frame_roundtrip():
    a, b = socket.socketpair()
    try:
        payload = np.arange(64, dtype=np.int8).tobytes()
        send_frame(a, {"t": "bn", "frm": 3, "round": 7, "score": -12.5},
                   payload)
        send_frame(a, {"t": "hb", "frm": 1})
        f = b.makefile("rb")
        h1, p1 = recv_frame(f)
        h2, p2 = recv_frame(f)
        assert h1["t"] == "bn" and h1["round"] == 7 and p1 == payload
        assert h2 == {"t": "hb", "frm": 1} and p2 == b""
    finally:
        a.close()
        b.close()


def test_mailbox_double_buffer():
    box = Mailbox()
    stop = threading.Event()
    g0 = np.zeros((3, 3), np.int8)
    g1 = np.eye(3, dtype=np.int8)
    box.put(0, (g0, -1.0, 0))
    box.put(1, (g1, -2.0, 0))        # round t+1 buffered while t unconsumed
    box.put(0, (g1, -9.0, 1))        # duplicate round: first write wins
    got0 = box.get(0, stop, timeout=1.0)
    got1 = box.get(1, stop, timeout=1.0)
    assert got0[1] == -1.0 and np.array_equal(got0[0], g0)
    assert got1[1] == -2.0
    box.drop_below(5)
    assert box.get(1, stop, timeout=0.05) is None


# ---------------------------------------------------------------------------
# Threaded ring vs the lockstep oracle
# ---------------------------------------------------------------------------

MAX_ROUNDS = 4


def _problem(seed=2, n=8, m=400):
    rng = np.random.default_rng(seed)
    bn = random_bn(rng, n=n, n_edges=int(1.3 * n), max_parents=2)
    data = forward_sample(bn, m, rng)
    return bn, data


def _host_ring(data, arities, masks, cfg, max_rounds=MAX_ROUNDS):
    """Lockstep oracle: per-member keeps of the last globally-improving
    round (the same rule as core/ring._ring_body and the async verdicts)."""
    k, n, _ = masks.shape
    graphs = [np.zeros((n, n), np.int8) for _ in range(k)]
    best_g, best_s = list(graphs), [-np.inf] * k
    best, go, rnd = -np.inf, True, 0
    while go and rnd < max_rounds:
        preds = [graphs[(i - 1) % k] for i in range(k)]
        new_g, new_s = [], []
        for i in range(k):
            init = fusion.fusion_edge_union(
                graphs[i], preds[i]).astype(np.int8)
            res = ges_host(data, arities, init_adj=init, allowed=masks[i],
                           config=cfg)
            new_g.append(res.adj)
            new_s.append(res.score)
        graphs, rnd = new_g, rnd + 1
        round_best = max(new_s)
        go = round_best > best + cfg.tol
        if go:
            best_g, best_s = new_g, new_s
        best = max(best, round_best)
    return np.stack(best_g), np.array(best_s), rnd


def test_async_threads_match_lockstep_oracle():
    bn, data = _problem()
    cfg = GESConfig(max_q=256, counts_impl="fused")
    masks = partition.partition_edges(data, bn.arities, 2)
    out = run_ring_async_threads(data, bn.arities, masks, config=cfg,
                                 max_rounds=MAX_ROUNDS, wall_limit_s=240.0)
    gH, sH, rH = _host_ring(data, bn.arities, masks, cfg)
    assert not out["timed_out"]
    assert out["rounds"] == rH
    assert np.array_equal(out["graphs"], gH)
    assert np.allclose(out["scores"], sH, rtol=1e-5, atol=1e-2)
    # the overlap claim: blocked-wait is a sliver of sweep time per member
    for i in out["survivors"]:
        t = out["members"][i]["timings"]
        assert np.sum(t["wait_us"]) < 0.5 * np.sum(t["sweep_us"])


def test_async_threads_elastic_kill_one_member():
    bn, data = _problem(seed=3, n=8)
    cfg = GESConfig(max_q=256, counts_impl="fused")
    masks = partition.partition_edges(data, bn.arities, 3)
    out = run_ring_async_threads(
        data, bn.arities, masks, config=cfg, max_rounds=6,
        die_member=1, die_after_round=1, hb_timeout_s=1.5,
        wall_limit_s=240.0)
    assert not out["timed_out"]
    assert out["survivors"] == [0, 2]
    assert out["live"] == [0, 2]
    assert np.isfinite(out["best_score"])
    # both survivors recorded the death (one by heartbeat, one by gossip)
    for i in out["survivors"]:
        assert [d["victim"] for d in out["members"][i]["deaths"]] == [1]
    # the dead member's E_1 was folded into its ring predecessor: member
    # 0's final restricted width covers the union, so the subsets the
    # survivors swept stay a complete cover of the original partition
    vias = {d["via"] for i in out["survivors"]
            for d in out["members"][i]["deaths"]}
    assert "heartbeat" in vias


def test_cges_async_engine_matches_jax_engine():
    from repro.core import cges

    bn, data = _problem()
    cfg = GESConfig(max_q=256, counts_impl="fused")
    masks = partition.partition_edges(data, bn.arities, 2)
    r_async = cges(data, bn.arities, k=2, limit=False, config=cfg,
                   engine="async", max_rounds=MAX_ROUNDS, edge_masks=masks)
    r_jax = cges(data, bn.arities, k=2, limit=False, config=cfg,
                 engine="jax", max_rounds=MAX_ROUNDS, edge_masks=masks)
    assert r_async.rounds == r_jax.rounds
    assert np.array_equal(r_async.adj, r_jax.adj)
    assert abs(r_async.score - r_jax.score) <= 1e-3
    assert np.allclose(r_async.ring_scores, r_jax.ring_scores, atol=1e-3)


# ---------------------------------------------------------------------------
# Multi-process launcher (the CI ring-async leg runs these)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_two_process_jax_distributed_parity():
    """2 OS processes form a jax.distributed cluster (bootstrap) and run
    the async ring over the socket data plane; final best score must match
    the single-process lockstep oracle within tol on the seeded problem."""
    code = textwrap.dedent("""
        import os
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, "src")
        import numpy as np
        from repro.core import GESConfig, partition
        from repro.launch.ring_async_run import launch_ring
        from tests.test_ring_async import _host_ring, _problem

        bn, data = _problem()
        cfg_kw = dict(max_q=256, counts_impl="fused")
        masks = partition.partition_edges(data, bn.arities, 2)
        agg = launch_ring(data, bn.arities, masks, config_kwargs=cfg_kw,
                          max_rounds=4, wall_limit_s=240.0,
                          jax_distributed=True, verbose=False)
        gH, sH, rH = _host_ring(data, bn.arities, masks,
                                GESConfig(**cfg_kw))
        assert agg["survivors"] == [0, 1], agg["exit_codes"]
        assert not agg["timed_out"]
        assert agg["rounds"] == rH, (agg["rounds"], rH)
        assert np.array_equal(agg["graphs"], gH)
        assert abs(agg["best_score"] - sH.max()) <= 1e-2
        print("PROC_PARITY_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, cwd=REPO,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "PROC_PARITY_OK" in r.stdout, r.stderr[-3000:]


@pytest.mark.slow
def test_three_process_kill_one_member():
    """One of 3 OS processes hard-exits (os._exit(13)) after round 1; the
    survivors must detect it, re-partition its edge subset, re-stitch the
    ring and converge.  jax.distributed stays OFF here — its coordination
    service terminates surviving processes when a peer dies."""
    code = textwrap.dedent("""
        import os
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, "src")
        import numpy as np
        from repro.core import partition
        from repro.launch.ring_async_run import launch_ring
        from tests.test_ring_async import _problem

        bn, data = _problem(seed=3)
        masks = partition.partition_edges(data, bn.arities, 3)
        agg = launch_ring(data, bn.arities, masks,
                          config_kwargs=dict(max_q=256,
                                             counts_impl="fused"),
                          max_rounds=6, hb_timeout_s=2.0,
                          wall_limit_s=240.0, die_member=1,
                          die_after_round=1, verbose=False)
        assert agg["exit_codes"][1] == 13, agg["exit_codes"]
        assert agg["survivors"] == [0, 2], agg["exit_codes"]
        assert agg["live"] == [0, 2]
        assert not agg["timed_out"]
        assert np.isfinite(agg["best_score"])
        for i in agg["survivors"]:
            deaths = agg["members"][i]["deaths"]
            assert [d["victim"] for d in deaths] == [1], (i, deaths)
        print("PROC_ELASTIC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, cwd=REPO,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "PROC_ELASTIC_OK" in r.stdout, r.stderr[-3000:]


def test_launch_ring_rejects_kill_drill_with_jax_distributed():
    with pytest.raises(ValueError, match="coordination service"):
        from repro.launch.ring_async_run import launch_ring
        launch_ring(np.zeros((4, 2), np.int64), np.array([2, 2]),
                    np.zeros((2, 2, 2), bool), config_kwargs={},
                    jax_distributed=True, die_member=0, die_after_round=0)
