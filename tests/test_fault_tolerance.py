"""Fault tolerance: cGES round checkpoint/resume + elastic ring repair."""
import numpy as np
import pytest

from repro.core import GESConfig, partition
from repro.core.cges import edge_add_limit
from repro.core.dag import is_dag_np
from repro.data.bn import forward_sample, random_bn
from repro.launch.cges_run import ring_rounds


@pytest.fixture(scope="module")
def case():
    rng = np.random.default_rng(21)
    bn = random_bn(rng, n=12, n_edges=15, max_parents=3)
    data = forward_sample(bn, 800, rng)
    return bn, data


def test_ring_checkpoint_resume_identical(case, tmp_path):
    bn, data = case
    config = GESConfig(max_q=256)
    masks = partition.partition_edges(data, bn.arities, 3)
    lim = edge_add_limit(bn.n, 3)

    # full run
    adj_a, score_a, rounds_a, _ = ring_rounds(
        data, bn.arities, masks, config, lim, max_rounds=8, verbose=False)

    # interrupted run: 2 rounds, then resume from checkpoint
    ck = str(tmp_path)
    adj_p, score_p, r_p, _ = ring_rounds(
        data, bn.arities, masks, config, lim, max_rounds=2,
        ckpt_dir=ck, verbose=False)
    adj_b, score_b, rounds_b, _ = ring_rounds(
        data, bn.arities, masks, config, lim, max_rounds=8,
        ckpt_dir=ck, verbose=False)
    assert rounds_b == rounds_a
    assert np.isclose(score_a, score_b)
    assert np.array_equal(adj_a, adj_b)


def test_elastic_repair_keeps_cover_and_dag(case):
    bn, data = case
    config = GESConfig(max_q=256)
    masks = partition.partition_edges(data, bn.arities, 4)
    adj, score, rounds, masks2 = ring_rounds(
        data, bn.arities, masks, config, edge_add_limit(bn.n, 4),
        max_rounds=6, fail_at_round=1, fail_member=1, verbose=False)
    assert masks2.shape[0] == 3
    off = ~np.eye(bn.n, dtype=bool)
    assert np.all(masks2.sum(axis=0)[off] == 1)
    assert is_dag_np(adj)
    assert np.isfinite(score)


def test_failed_member_zero_is_predecessor_of_last(case):
    bn, data = case
    masks = partition.partition_edges(data, bn.arities, 3)
    out = partition.remerge_failed(masks, 0)
    # member 0's predecessor is member k-1 -> last subset absorbs E_0
    assert out.shape[0] == 2
    assert np.all(out[1] >= masks[0])
