"""Unified sweep engine (core/sweeps): fused BES delete-by-marginalization
equality vs the loop engine (mixed arities, padded r_max, empty parent set,
max_q guard), restricted-W columns, masked-convention regressions, and ring
trajectory invariance across counts_impls."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bdeu, sweeps
from repro.core.sweeps import sweep
from repro.data.bn import forward_sample, random_bn

FUSED_IMPLS = ["fused", "fused_pallas"]


@pytest.fixture(scope="module")
def mixed_case():
    """Mixed arities with most columns below r_max (dense-padding exercised)."""
    rng = np.random.default_rng(5)
    arities = np.array([2, 3, 4, 2, 3, 2, 4, 2, 3, 2], dtype=np.int64)
    n = arities.size
    data = np.stack([rng.integers(0, a, size=900) for a in arities], 1)
    return data.astype(np.int64), arities


def _jnp(data, arities):
    return (jnp.asarray(data.astype(np.int32)),
            jnp.asarray(arities.astype(np.int32)))


def _delete_col(data, arities, adj, y, impl, max_q=256, pids=None):
    dj, aj = _jnp(data, arities)
    return np.asarray(sweep(
        dj, aj, jnp.asarray(adj), kind="delete", y=y, pids=pids, ess=10.0,
        max_q=max_q, r_max=int(arities.max()), counts_impl=impl))


# ---------------------------------------------------------------------------
# Fused BES delete: one family-table build, marginalized per parent slot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", FUSED_IMPLS)
def test_delete_column_matches_host_oracle(mixed_case, impl):
    """Fused delete deltas == exact host-oracle deltas at every parent."""
    data, arities = mixed_case
    n = arities.size
    adj = np.zeros((n, n), dtype=np.int8)
    pa = [1, 2, 6]                       # arities 3, 4, 4 -> q0 = 48
    adj[pa, 0] = 1
    col = _delete_col(data, arities, adj, 0, impl)
    base = bdeu.local_score_np(data, arities, 0, pa)
    for x in range(n):
        if adj[x, 0]:
            want = bdeu.local_score_np(
                data, arities, 0, [p for p in pa if p != x]) - base
            assert np.isclose(col[x], want, rtol=2e-5, atol=1e-3), x
        else:
            assert np.isneginf(col[x])   # illegal toggle, engine-masked


@pytest.mark.parametrize("impl", FUSED_IMPLS)
def test_delete_column_matches_loop_engine(mixed_case, impl):
    """Fused == loop delete column entry-for-entry (both engine-masked)."""
    data, arities = mixed_case
    n = arities.size
    adj = np.zeros((n, n), dtype=np.int8)
    adj[[0, 4, 8], 3] = 1
    adj[[2, 5], 7] = 1
    for y in (3, 7):
        col_loop = _delete_col(data, arities, adj, y, "segment")
        col_fus = _delete_col(data, arities, adj, y, impl)
        assert np.array_equal(np.isneginf(col_loop), np.isneginf(col_fus))
        f = np.isfinite(col_loop)
        assert np.allclose(col_loop[f], col_fus[f], rtol=1e-4, atol=2e-3)


def test_delete_column_empty_parent_set(mixed_case):
    """With Pa_y empty every delete is illegal: whole column -inf, no NaNs,
    identical under every backend."""
    data, arities = mixed_case
    n = arities.size
    adj = np.zeros((n, n), dtype=np.int8)
    for impl in ["segment"] + FUSED_IMPLS:
        col = _delete_col(data, arities, adj, 2, impl)
        assert np.all(np.isneginf(col)), impl
        assert not np.isnan(col).any(), impl


def test_delete_column_max_q_guard(mixed_case):
    """Candidates whose REDUCED family still overflows max_q are -inf with
    the loop engine's exact guard convention; deletes that fit are finite."""
    data, arities = mixed_case
    n = arities.size
    adj = np.zeros((n, n), dtype=np.int8)
    pa = [1, 2, 6]                        # q0 = 3*4*4 = 48
    adj[pa, 0] = 1
    # max_q = 24: the family itself overflows; removing x=1 leaves q=16 (ok),
    # removing x=2 or x=6 leaves q=12 (ok) -> deltas vs the -inf base are
    # +inf under BOTH engines (identical trajectory decisions), and the
    # engines' +/-inf patterns must agree entry-for-entry.
    col_loop = _delete_col(data, arities, adj, 0, "segment", max_q=24)
    col_fus = _delete_col(data, arities, adj, 0, "fused", max_q=24)
    assert np.array_equal(np.isposinf(col_loop), np.isposinf(col_fus))
    assert np.array_equal(np.isneginf(col_loop), np.isneginf(col_fus))
    assert np.isposinf(col_loop[np.asarray(pa)]).all()
    # max_q = 12: removing one arity-4 parent leaves q=12 (fits: +inf delta
    # vs the -inf base) but removing the arity-3 parent leaves q=16 -> the
    # REDUCED family is guarded -inf too, and -inf - (-inf) = NaN under both
    # engines — the guard conventions must agree entry-for-entry.
    col_loop = _delete_col(data, arities, adj, 0, "segment", max_q=12)
    col_fus = _delete_col(data, arities, adj, 0, "fused", max_q=12)
    assert np.array_equal(np.isposinf(col_loop), np.isposinf(col_fus))
    assert np.array_equal(np.isnan(col_loop), np.isnan(col_fus))
    assert np.isnan(col_loop[1]) and np.isnan(col_fus[1])
    assert np.isposinf(col_fus[2]) and np.isposinf(col_fus[6])


@pytest.mark.parametrize("impl", FUSED_IMPLS)
def test_delete_matrix_matches_loop_engine(mixed_case, impl):
    """Full (n, n) BES delta matrix through the unified engine: fused ==
    loop everywhere (the ges_jit BES initialization path)."""
    data, arities = mixed_case
    n = arities.size
    rng = np.random.default_rng(1)
    adj = np.zeros((n, n), dtype=np.int8)
    for y in range(n):
        for x in rng.choice(n, size=2, replace=False):
            if x != y:
                adj[x, y] = 1
    dj, aj = _jnp(data, arities)
    kw = dict(kind="delete", ess=10.0, max_q=256, r_max=int(arities.max()))
    D_loop = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                              counts_impl="segment", **kw))
    D_fus = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                             counts_impl=impl, **kw))
    assert np.array_equal(np.isneginf(D_loop), np.isneginf(D_fus))
    f = np.isfinite(D_loop)
    assert np.allclose(D_loop[f], D_fus[f], rtol=1e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# Restricted-W columns (ring E_i subsets)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["insert", "delete"])
@pytest.mark.parametrize("impl", FUSED_IMPLS)
def test_restricted_column_matches_loop(mixed_case, kind, impl):
    """(W,) restricted columns agree with the loop engine entry-for-entry,
    including illegal pids (self-pads, wrong edge state) masked to -inf."""
    data, arities = mixed_case
    n = arities.size
    adj = np.zeros((n, n), dtype=np.int8)
    adj[[1, 4], 0] = 1
    y = 0
    pids = jnp.asarray(np.array([1, 3, 4, 7, 9, y, y], dtype=np.int32))
    dj, aj = _jnp(data, arities)
    kw = dict(kind=kind, y=y, pids=pids, ess=10.0, max_q=256,
              r_max=int(arities.max()))
    col_loop = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                                counts_impl="segment", **kw))
    col_fus = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                               counts_impl=impl, **kw))
    assert col_fus.shape == (7,)
    assert np.array_equal(np.isneginf(col_loop), np.isneginf(col_fus))
    f = np.isfinite(col_loop)
    assert f.any()
    assert np.allclose(col_loop[f], col_fus[f], rtol=1e-4, atol=2e-3)


def test_restricted_kernel_contracts_w_columns_not_n(mixed_case):
    """The restricted Pallas variant's counts slab is (r_max, max_q,
    W*r_max): the contraction width — and hence fused cost — scales with the
    candidate subset W, not the full n."""
    from repro.kernels.bdeu_sweep import sweep_counts, sweep_counts_restricted

    data, arities = mixed_case
    dj, aj = _jnp(data, arities)
    r_max = int(arities.max())
    m, n = data.shape
    cfg = jnp.zeros((m,), jnp.int32)
    child = dj[:, 0]
    pids = jnp.asarray(np.array([1, 3, 4], dtype=np.int32))
    full = sweep_counts(cfg, child, dj, max_q=32, r_max=r_max)
    sub = sweep_counts_restricted(cfg, child, dj, pids, max_q=32, r_max=r_max)
    assert full.shape == (r_max, 32, n * r_max)
    assert sub.shape == (r_max, 32, 3 * r_max)
    # gathered-before-contraction == gathered-after-contraction
    want = np.asarray(full).reshape(r_max, 32, n, r_max)[:, :, np.asarray(pids)]
    assert np.array_equal(np.asarray(sub).reshape(r_max, 32, 3, r_max), want)


def test_sweep_matrix_rejects_pids():
    data = np.zeros((4, 3), dtype=np.int64)
    ar = np.full(3, 2)
    dj, aj = _jnp(data, ar)
    with pytest.raises(ValueError):
        sweep(dj, aj, jnp.zeros((3, 3), jnp.int8), kind="insert",
              pids=jnp.arange(2), ess=10.0, max_q=8, r_max=2)
    with pytest.raises(ValueError):
        sweep(dj, aj, jnp.zeros((3, 3), jnp.int8), kind="reverse",
              ess=10.0, max_q=8, r_max=2)


def test_sweep_rejects_bad_candidate_ids():
    """Over-long or out-of-range pids/pid_table raise a clear ValueError
    instead of flowing into the gather as silent wrong shapes."""
    n = 5
    data = np.zeros((8, n), dtype=np.int64)
    ar = np.full(n, 2)
    dj, aj = _jnp(data, ar)
    adj = jnp.zeros((n, n), jnp.int8)
    kw = dict(ess=10.0, max_q=8, r_max=2)
    with pytest.raises(ValueError, match="candidates per column"):
        sweep(dj, aj, adj, kind="insert", y=0,
              pids=jnp.zeros(n + 1, jnp.int32), **kw)
    with pytest.raises(ValueError, match="out-of-range"):
        sweep(dj, aj, adj, kind="insert", y=0,
              pids=jnp.asarray([0, n], dtype=jnp.int32), **kw)
    with pytest.raises(ValueError, match="out-of-range"):
        sweep(dj, aj, adj, kind="insert", y=0,
              pids=jnp.asarray([-1, 1], dtype=jnp.int32), **kw)
    with pytest.raises(ValueError, match="integer"):
        sweep(dj, aj, adj, kind="insert", y=0,
              pids=jnp.asarray([0.0, 1.0]), **kw)
    with pytest.raises(ValueError, match="candidates per column"):
        sweep(dj, aj, adj, kind="insert",
              pid_table=jnp.zeros((n, n + 2), jnp.int32), **kw)
    with pytest.raises(ValueError, match="out-of-range"):
        sweep(dj, aj, adj, kind="insert",
              pid_table=jnp.full((n, 2), n, dtype=jnp.int32), **kw)
    with pytest.raises(ValueError, match=r"\(n, W\)"):
        sweep(dj, aj, adj, kind="insert",
              pid_table=jnp.zeros((n - 1, 2), jnp.int32), **kw)
    with pytest.raises(ValueError, match="not both"):
        sweep(dj, aj, adj, kind="insert", y=0,
              pid_table=jnp.zeros((n, 2), jnp.int32), **kw)


def test_unknown_counts_impl_fails_loudly():
    """A typo'd backend (config or REPRO_COUNTS_IMPL) must raise, not
    silently fall through the dispatch chains to 'segment'."""
    from repro.core import GESConfig

    with pytest.raises(ValueError, match="unknown counts_impl"):
        GESConfig(counts_impl="fuesd")
    data = np.zeros((4, 3), dtype=np.int64)
    ar = np.full(3, 2)
    dj, aj = _jnp(data, ar)
    with pytest.raises(ValueError, match="unknown counts_impl"):
        sweep(dj, aj, jnp.zeros((3, 3), jnp.int8), kind="insert",
              counts_impl="Fused", ess=10.0, max_q=8, r_max=2)


# ---------------------------------------------------------------------------
# Restricted (W, n) matrix sweeps (the compiled ring's per-round rescoring)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["insert", "delete"])
@pytest.mark.parametrize("impl", ["segment"] + FUSED_IMPLS)
def test_restricted_matrix_matches_full(mixed_case, kind, impl):
    """sweep(pid_table=...) returns the (W, n) matrix whose entry [w, y]
    equals the full (n, n) loop matrix at [pid_table[y, w], y], with
    self-pads -inf — under every backend."""
    from repro.core.partition import pid_table_from_allowed

    data, arities = mixed_case
    n = arities.size
    rng = np.random.default_rng(7)
    adj = np.zeros((n, n), dtype=np.int8)
    adj[[1, 4], 0] = 1
    adj[[0, 2, 6], 5] = 1
    allowed = rng.random((n, n)) < 0.4
    allowed[:, 8] = False                 # empty E_i column: all self-pads
    np.fill_diagonal(allowed, False)
    tbl = pid_table_from_allowed(allowed)
    W = tbl.shape[1]
    dj, aj = _jnp(data, arities)
    kw = dict(kind=kind, ess=10.0, max_q=256, r_max=int(arities.max()))
    D_full = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                              counts_impl="segment", **kw))
    D_res = np.asarray(sweep(dj, aj, jnp.asarray(adj), counts_impl=impl,
                             pid_table=jnp.asarray(tbl), **kw))
    assert D_res.shape == (W, n)
    assert np.all(np.isneginf(D_res[:, 8]))
    for y in range(n):
        for w in range(W):
            x = tbl[y, w]
            if x == y:
                assert np.isneginf(D_res[w, y])
            elif np.isfinite(D_full[x, y]):
                assert np.isclose(D_res[w, y], D_full[x, y],
                                  rtol=1e-4, atol=2e-3), (y, w)
            else:
                assert np.isneginf(D_res[w, y]) == np.isneginf(D_full[x, y])


@pytest.mark.parametrize("kind", ["insert", "delete"])
@pytest.mark.parametrize("impl", ["segment", "fused"])
def test_restricted_matrix_bitwise_equals_full(mixed_case, kind, impl):
    """Restricted entries are BITWISE equal to the full-n matrix (same
    engine): the compiled ring's full-n tie-breaking argmax
    (ges._masked_argmax_mapped) relies on exact value equality between the
    (W, n) and (n, n) programs — 1-ulp drift would let score-equivalent
    ties (x->y vs y->x) resolve differently."""
    from repro.core.partition import pid_table_from_allowed

    data, arities = mixed_case
    n = arities.size
    rng = np.random.default_rng(13)
    allowed = rng.random((n, n)) < 0.5
    np.fill_diagonal(allowed, False)
    # parents drawn inside `allowed` so delete entries are plentiful
    adj = np.zeros((n, n), dtype=np.int8)
    for y in range(n):
        cand = np.flatnonzero(allowed[:, y])
        for x in cand[:2]:
            adj[x, y] = 1
    tbl = pid_table_from_allowed(allowed)
    dj, aj = _jnp(data, arities)
    kw = dict(kind=kind, ess=10.0, max_q=256, r_max=int(arities.max()))
    D_full = np.asarray(sweep(dj, aj, jnp.asarray(adj), counts_impl=impl,
                              **kw))
    D_res = np.asarray(sweep(dj, aj, jnp.asarray(adj), counts_impl=impl,
                             pid_table=jnp.asarray(tbl), **kw))
    checked = 0
    for y in range(n):
        for w in range(tbl.shape[1]):
            x = tbl[y, w]
            if x == y:
                continue
            a, b = D_res[w, y], D_full[x, y]
            if np.isfinite(b) or np.isfinite(a):
                assert a == b, (y, w, x, a, b)    # bitwise, not isclose
                checked += 1
    assert checked > 10


# ---------------------------------------------------------------------------
# End-to-end trajectory invariance
# ---------------------------------------------------------------------------

def test_ges_host_bes_trajectory_identity(mixed_case):
    """A BES-heavy host run (dense init graph) takes the identical greedy
    delete trajectory under the loop and fused engines."""
    from repro.core import GESConfig, ges_host
    from repro.core.dag import is_dag_np

    data, arities = mixed_case
    n = arities.size
    rng = np.random.default_rng(3)
    init = np.zeros((n, n), dtype=np.int8)
    for y in range(1, n):                 # DAG: parents only from lower ids
        for x in rng.choice(y, size=min(2, y), replace=False):
            init[x, y] = 1
    res = {}
    for impl in ("segment", "fused", "fused_pallas"):
        res[impl] = ges_host(data, arities, init_adj=init,
                             config=GESConfig(max_q=256, counts_impl=impl),
                             phases="bes")
    assert res["segment"].n_deletes > 0    # the BES phase actually ran
    for impl in FUSED_IMPLS:
        assert np.array_equal(res[impl].adj, res["segment"].adj)
        assert np.isclose(res[impl].score, res["segment"].score, rtol=1e-9)
    assert is_dag_np(res["segment"].adj)


def test_ring_cges_trajectory_invariance():
    """The compiled W-wide ring (pid_table threaded through the shard_map
    while_loop) is trajectory-identical to (a) the old full-n-masked
    compiled path, (b) every other counts_impl backend, and (c) the
    host-engine cGES driver (ges_host + fusion_edge_union round loop), on
    k in {1, 2} meshes (subprocess: needs a multi-device host platform)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, "src")
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import GESConfig, fusion, ges_host, partition
        from repro.core.ring import RingSpec, ring_cges
        from repro.data.bn import forward_sample, random_bn

        rng = np.random.default_rng(2)
        bn = random_bn(rng, n=8, n_edges=9, max_parents=2)
        data = forward_sample(bn, 400, rng)
        n = bn.n
        MAX_ROUNDS = 3

        def host_ring(masks, k, cfg):
            '''Host-engine mirror of _ring_body: ges_host processes, the
            same one-hop fusion and convergence rule, keeping the graphs
            of the last globally-improving round (Algorithm 1 best BNs).'''
            graphs = [np.zeros((n, n), np.int8) for _ in range(k)]
            best_g, best_s = list(graphs), [-np.inf] * k
            best, go, rnd = -np.inf, True, 0
            while go and rnd < MAX_ROUNDS:
                preds = [graphs[(i - 1) % k] for i in range(k)]
                new_g, new_s = [], []
                for i in range(k):
                    init = fusion.fusion_edge_union(
                        graphs[i], preds[i]).astype(np.int8)
                    res = ges_host(data, bn.arities, init_adj=init,
                                   allowed=masks[i], config=cfg)
                    new_g.append(res.adj); new_s.append(res.score)
                graphs, rnd = new_g, rnd + 1
                round_best = max(new_s)
                go = round_best > best + cfg.tol
                if go:
                    best_g, best_s = new_g, new_s
                best = max(best, round_best)
            return np.stack(best_g), np.array(best_s), rnd

        for k in (1, 2):
            masks = partition.partition_edges(data, bn.arities, k)
            mesh = Mesh(np.array(jax.devices()[:k]), ("ring",))
            spec = RingSpec(k=k, max_rounds=MAX_ROUNDS)
            impls = (("segment", "fused", "fused_pallas") if k == 2
                     else ("segment", "fused"))
            out = {}
            for impl in impls:
                cfg = GESConfig(max_q=64, counts_impl=impl)
                gW, sW, rW = ring_cges(data, bn.arities, masks, mesh,
                                       spec, cfg, restricted=True)
                gF, sF, rF = ring_cges(data, bn.arities, masks, mesh,
                                       spec, cfg, restricted=False)
                assert np.array_equal(gW, gF), (k, impl, "W vs full-n")
                assert np.allclose(sW, sF, rtol=1e-6), (k, impl)
                assert rW == rF, (k, impl)
                out[impl] = (gW, sW)
            for impl in impls[1:]:
                assert np.array_equal(out[impls[0]][0], out[impl][0]), \\
                    (k, impl, "impl mismatch")
                assert np.allclose(out[impls[0]][1], out[impl][1],
                                   rtol=1e-6)
            gH, sH, rH = host_ring(masks, k,
                                   GESConfig(max_q=64,
                                             counts_impl="segment"))
            assert np.array_equal(out["segment"][0], gH), (k, "vs host")
            assert np.allclose(out["segment"][1], sH,
                               rtol=1e-5, atol=1e-2), (k, "host scores")
            assert out["segment"][0].any()   # the ring actually learned
        print("RING_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "RING_OK" in r.stdout, r.stderr[-3000:]


def test_ring_cges_trajectory_k3_k4():
    """ppermute neighbor wiring on non-trivial cycles: k in {3, 4} rings
    (odd and larger-even), pinned against the host-engine oracle, with the
    restricted W-wide pid_table path and the persistent family cache each
    exercised on the multi-hop cycle (subprocess: forced host devices).

    max_q=256 keeps every fused-init family under the compiled-table guard
    for these seeds: when the guard bites a base family but not its
    reduced families, host and compiled BES legitimately diverge (see
    bdeu.graph_score_jax) and the cross-engine pin would be vacuous."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, "src")
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import GESConfig, fusion, ges_host, partition
        from repro.core.ring import RingSpec, ring_cges
        from repro.data.bn import forward_sample, random_bn

        rng = np.random.default_rng(5)
        bn = random_bn(rng, n=9, n_edges=11, max_parents=2)
        data = forward_sample(bn, 400, rng)
        n = bn.n
        MAX_ROUNDS = 3

        def host_ring(masks, k, cfg):
            graphs = [np.zeros((n, n), np.int8) for _ in range(k)]
            best_g, best_s = list(graphs), [-np.inf] * k
            best, go, rnd = -np.inf, True, 0
            while go and rnd < MAX_ROUNDS:
                preds = [graphs[(i - 1) % k] for i in range(k)]
                new_g, new_s = [], []
                for i in range(k):
                    init = fusion.fusion_edge_union(
                        graphs[i], preds[i]).astype(np.int8)
                    res = ges_host(data, bn.arities, init_adj=init,
                                   allowed=masks[i], config=cfg)
                    new_g.append(res.adj); new_s.append(res.score)
                graphs, rnd = new_g, rnd + 1
                round_best = max(new_s)
                go = round_best > best + cfg.tol
                if go:
                    best_g, best_s = new_g, new_s
                best = max(best, round_best)
            return np.stack(best_g), np.array(best_s), rnd

        for k, impl in ((3, "segment"), (4, "fused")):
            masks = partition.partition_edges(data, bn.arities, k)
            mesh = Mesh(np.array(jax.devices()[:k]), ("ring",))
            spec = RingSpec(k=k, max_rounds=MAX_ROUNDS)
            cfg = GESConfig(max_q=256, counts_impl=impl)
            # restricted (W-wide pid_table) vs full-n on the k-cycle
            gW, sW, rW = ring_cges(data, bn.arities, masks, mesh,
                                   spec, cfg, restricted=True)
            gF, sF, rF = ring_cges(data, bn.arities, masks, mesh,
                                   spec, cfg, restricted=False)
            assert np.array_equal(gW, gF), (k, "W vs full-n")
            assert np.allclose(sW, sF, rtol=1e-6), (k,)
            assert rW == rF, (k,)
            # persistent family cache on the multi-hop cycle: bitwise pin
            cfg_fc = GESConfig(max_q=256, counts_impl=impl,
                               family_cache=True)
            gC, sC, rC, stats = ring_cges(data, bn.arities, masks, mesh,
                                          spec, cfg_fc, restricted=True,
                                          return_cache_stats=True)
            assert np.array_equal(gC, gW), (k, "family-cache drift")
            assert np.allclose(sC, sW, rtol=1e-6), (k,)
            assert rC == rW, (k,)
            # host-engine oracle: the cycle's one-hop information flow
            gH, sH, rH = host_ring(masks, k,
                                   GESConfig(max_q=256,
                                             counts_impl="segment"))
            assert np.array_equal(gW, gH), (k, "vs host oracle")
            assert np.allclose(sW, sH, rtol=1e-5, atol=1e-2), (k,)
            assert rW == rH, (k,)
            assert gW.any()
        print("RING_K34_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "RING_K34_OK" in r.stdout, r.stderr[-3000:]
