"""Sharding rules validated symbolically for all 10 FULL configs against the
production mesh geometry (no 256 devices needed: param_spec only reads
axis_names / shape)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch import specs as sp
from repro.models import sharding as shd


class MeshStub:
    """Duck-typed stand-in: param_spec/cache_specs only touch these attrs."""
    def __init__(self, axes):
        self.axis_names = tuple(a for a, _ in axes)
        self.shape = dict(axes)


POD1 = MeshStub([("data", 16), ("model", 16)])
POD2 = MeshStub([("pod", 2), ("data", 16), ("model", 16)])


def _axis_size(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for e in entry:
            n *= mesh.shape[e]
        return n
    return mesh.shape[entry]


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [POD1, POD2], ids=["pod1", "pod2"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    shapes = sp.abstract_params(cfg)
    specs = shd.param_specs(cfg, mesh, shapes)
    leaves_spec = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    leaves_shape = jax.tree.leaves(shapes)
    assert len(leaves_spec) == len(leaves_shape)
    for spec, leaf in zip(leaves_spec, leaves_shape):
        assert len(spec) <= leaf.ndim, (arch, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec)):
            size = _axis_size(mesh, entry)
            assert dim % size == 0, (arch, spec, leaf.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    for shape_name in ("decode_32k", "long_500k"):
        shp = SHAPES[shape_name]
        cache = sp.abstract_cache(cfg, shp.global_batch, shp.seq_len)
        specs = shd.cache_specs(cfg, POD1, cache,
                                shard_seq=(shp.global_batch == 1))
        for spec, leaf in zip(
                jax.tree.leaves(specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec)),
                jax.tree.leaves(cache)):
            for dim, entry in zip(leaf.shape, tuple(spec)):
                size = _axis_size(POD1, entry)
                assert dim % size == 0, (arch, shape_name, spec, leaf.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_opt_specs_extend_but_stay_divisible(arch):
    cfg = get_config(arch)
    shapes = sp.abstract_params(cfg)
    ospecs = shd.opt_specs(cfg, POD1, shapes)
    for spec, leaf in zip(
            jax.tree.leaves(ospecs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec)),
            jax.tree.leaves(shapes)):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            assert dim % _axis_size(POD1, entry) == 0, (arch, spec, leaf.shape)


def test_head_padding_policy():
    """Every arch's padded head counts divide TP=16 and zero-mask exactness
    is covered by test_models.test_padded_heads_exact."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if cfg.n_heads:
            assert cfg.heads_pad % 16 == 0 or cfg.heads_pad == cfg.n_heads
            # group structure stays integral
            if cfg.n_kv_heads:
                assert cfg.heads_pad % cfg.n_kv_heads == 0


def test_dp_axes_by_mesh():
    from repro.models.sharding import dp_axes
    assert dp_axes(POD1) == ("data",)
    assert dp_axes(POD2) == ("pod", "data")
