"""BDeu scoring: host oracle vs jit-safe device engine vs Pallas path."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bdeu
from repro.data.bn import forward_sample, random_bn


def _rand_case(seed, n=6, m=300):
    rng = np.random.default_rng(seed)
    arities = rng.integers(2, 4, size=n)
    data = np.stack([rng.integers(0, a, size=m) for a in arities], axis=1)
    return data.astype(np.int32), arities.astype(np.int64)


@given(st.integers(0, 10_000), st.integers(0, 5), st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_local_score_host_vs_device(seed, child, n_parents):
    data, arities = _rand_case(seed)
    n = data.shape[1]
    rng = np.random.default_rng(seed + 1)
    parents = rng.choice([i for i in range(n) if i != child],
                         size=min(n_parents, n - 1), replace=False)
    host = bdeu.local_score_np(data, arities, child, list(parents))
    mask = np.zeros(n, dtype=bool)
    mask[parents] = True
    for impl in ("segment", "onehot"):
        dev = bdeu.local_score_masked(
            jnp.asarray(data), jnp.asarray(arities.astype(np.int32)),
            jnp.int32(child), jnp.asarray(mask), 10.0,
            max_q=64, r_max=int(arities.max()), counts_impl=impl)
        assert np.isclose(float(dev), host, rtol=2e-5, atol=1e-3), impl


def test_local_score_pallas_matches_host():
    data, arities = _rand_case(42)
    mask = np.zeros(data.shape[1], dtype=bool)
    mask[[1, 3]] = True
    host = bdeu.local_score_np(data, arities, 0, [1, 3])
    dev = bdeu.local_score_masked(
        jnp.asarray(data), jnp.asarray(arities.astype(np.int32)),
        jnp.int32(0), jnp.asarray(mask), 10.0,
        max_q=64, r_max=int(arities.max()), counts_impl="pallas")
    assert np.isclose(float(dev), host, rtol=2e-5, atol=1e-3)


def test_overflow_guard_returns_neg_inf():
    data, arities = _rand_case(3)
    mask = np.ones(data.shape[1], dtype=bool)
    mask[0] = False
    dev = bdeu.local_score_masked(
        jnp.asarray(data), jnp.asarray(arities.astype(np.int32)),
        jnp.int32(0), jnp.asarray(mask), 10.0,
        max_q=4, r_max=int(arities.max()))  # q >> max_q
    assert np.isneginf(float(dev))


def test_graph_score_decomposability(small_bn, small_data):
    ar = small_bn.arities
    total = bdeu.graph_score_np(small_data, ar, small_bn.adj)
    parts = sum(
        bdeu.local_score_np(small_data, ar, y,
                            list(np.flatnonzero(small_bn.adj[:, y])))
        for y in range(small_bn.n))
    assert np.isclose(total, parts)


def test_graph_score_jax_matches_np(small_bn, small_data):
    ar = small_bn.arities.astype(np.int32)
    host = bdeu.graph_score_np(small_data, small_bn.arities, small_bn.adj)
    dev = bdeu.graph_score_jax(
        jnp.asarray(small_data.astype(np.int32)), jnp.asarray(ar),
        jnp.asarray(small_bn.adj.astype(np.int8)), 10.0,
        max_q=256, r_max=int(ar.max()))
    assert np.isclose(float(dev), host, rtol=1e-5, atol=0.5)


def test_insert_deltas_match_direct(small_data, small_bn):
    """D[x, y] must equal score(y, Pa+x) - score(y, Pa) exactly."""
    ar = small_bn.arities
    n = small_bn.n
    adj = np.zeros((n, n), dtype=np.int8)
    adj[0, 1] = 1
    D = np.asarray(bdeu.insert_deltas(
        jnp.asarray(small_data.astype(np.int32)),
        jnp.asarray(ar.astype(np.int32)), jnp.asarray(adj),
        10.0, max_q=256, r_max=int(ar.max())))
    for (x, y) in [(2, 3), (0, 5), (4, 1)]:
        pa = list(np.flatnonzero(adj[:, y]))
        want = (bdeu.local_score_np(small_data, ar, y, pa + [x])
                - bdeu.local_score_np(small_data, ar, y, pa))
        assert np.isclose(D[x, y], want, rtol=2e-5, atol=1e-3)


def test_delete_deltas_match_direct(small_data, small_bn):
    ar = small_bn.arities
    adj = small_bn.adj.astype(np.int8)
    D = np.asarray(bdeu.delete_deltas(
        jnp.asarray(small_data.astype(np.int32)),
        jnp.asarray(ar.astype(np.int32)), jnp.asarray(adj),
        10.0, max_q=256, r_max=int(ar.max())))
    xs, ys = np.nonzero(adj)
    x, y = int(xs[0]), int(ys[0])
    pa = list(np.flatnonzero(adj[:, y]))
    pa_minus = [p for p in pa if p != x]
    want = (bdeu.local_score_np(small_data, ar, y, pa_minus)
            - bdeu.local_score_np(small_data, ar, y, pa))
    assert np.isclose(D[x, y], want, rtol=2e-5, atol=1e-3)


def test_pairwise_similarity_engines_agree(small_data, small_bn):
    ar = small_bn.arities
    s_host = bdeu.pairwise_similarity_np(small_data, ar)
    s_dev = np.asarray(bdeu.pairwise_similarity_jax(
        jnp.asarray(small_data.astype(np.int32)),
        jnp.asarray(ar.astype(np.int32)), 10.0, int(ar.max())))
    # device version is the asymmetric-then-symmetrized delta; same formula
    assert np.allclose(s_host, s_dev, rtol=1e-4, atol=2e-2)
    assert np.allclose(s_dev, s_dev.T, atol=1e-5)


def test_pairwise_similarity_fast_matches_oracle(small_data, small_bn):
    """The one-matmul all-pairs path must equal the per-pair host oracle."""
    ar = small_bn.arities
    s_fast = bdeu.pairwise_similarity_fast(small_data, ar)
    s_host = bdeu.pairwise_similarity_np(small_data, ar)
    assert np.allclose(s_fast, s_host, rtol=1e-8, atol=1e-6)
