"""Fused all-candidate delta-sweep engine: equality vs the host oracle and the
per-candidate loop engine, overflow guard, restricted-subset columns, and
end-to-end trajectory identity of ges_jit across counts_impls."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import GESConfig, bdeu, ges_host, ges_jit
from repro.core.sweeps import sweep
from repro.data.bn import forward_sample, random_bn

FUSED_IMPLS = ["fused", "fused_pallas"]


@pytest.fixture(scope="module")
def case():
    rng = np.random.default_rng(11)
    bn = random_bn(rng, n=12, n_edges=14, max_parents=3)
    data = forward_sample(bn, 1500, rng)
    return bn, data


def _jnp_inputs(bn, data):
    return (jnp.asarray(data.astype(np.int32)),
            jnp.asarray(bn.arities.astype(np.int32)))


def test_bdeu_sweep_engines_share_one_counts_contract():
    """bdeu's in-module jnp fused path and the kernel package's oracle are
    separate implementations of the same counts contract — pin them to each
    other so neither can drift (the Pallas kernel is validated against the
    latter, production "fused" scoring uses the former)."""
    import jax

    from repro.kernels.bdeu_sweep import sweep_counts_ref

    key = jax.random.PRNGKey(3)
    m, n, q, r = 513, 9, 33, 4
    k1, k2, k3 = jax.random.split(key, 3)
    cfg = jax.random.randint(k1, (m,), 0, q, dtype=jnp.int32)
    child = jax.random.randint(k2, (m,), 0, r, dtype=jnp.int32)
    data = jax.random.randint(k3, (m, n), 0, r, dtype=jnp.int32)
    got = bdeu._sweep_counts_segment(cfg, child, bdeu._onehot_all(data, r),
                                     max_q=q, r_max=r)
    want = sweep_counts_ref(cfg, child, data, max_q=q, r_max=r)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", FUSED_IMPLS)
def test_fused_column_matches_host_oracle(case, impl):
    """Fused sweep scores == per-family host oracle at every valid candidate."""
    bn, data = case
    dj, aj = _jnp_inputs(bn, data)
    n = bn.n
    y, pa = 2, [0, 5]
    pm = np.zeros(n, dtype=bool)
    pm[pa] = True
    scores = np.asarray(bdeu.fused_insert_scores(
        dj, aj, jnp.int32(y), jnp.asarray(pm), 10.0,
        max_q=256, r_max=int(bn.arities.max()), counts_impl=impl))
    for x in range(n):
        if x == y or pm[x]:
            continue  # garbage-by-convention entries (callers mask)
        want = bdeu.local_score_np(data, bn.arities, y, pa + [x])
        assert np.isclose(scores[x], want, rtol=2e-5, atol=1e-3), (x, impl)


@pytest.mark.parametrize("impl", FUSED_IMPLS)
def test_fused_deltas_match_segment(case, impl):
    """Full (n, n) insert-delta matrices agree with the loop engine
    everywhere (both engines share the duplicated-slot convention)."""
    bn, data = case
    dj, aj = _jnp_inputs(bn, data)
    n = bn.n
    adj = np.zeros((n, n), dtype=np.int8)
    adj[0, 2] = adj[5, 2] = adj[1, 4] = 1
    kw = dict(ess=10.0, max_q=256, r_max=int(bn.arities.max()))
    D_seg = np.asarray(bdeu.insert_deltas(
        dj, aj, jnp.asarray(adj), counts_impl="segment", **kw))
    D_fus = np.asarray(bdeu.insert_deltas(
        dj, aj, jnp.asarray(adj), counts_impl=impl, **kw))
    assert np.array_equal(np.isneginf(D_seg), np.isneginf(D_fus))
    finite = np.isfinite(D_seg)
    assert np.allclose(D_seg[finite], D_fus[finite], rtol=1e-4, atol=2e-3)


def test_fused_overflow_guard_matches_segment(case):
    """Candidates whose q0 * r_x exceeds max_q must be -inf, with the same
    guard mask as the loop engine (log-domain check)."""
    bn, data = case
    dj, aj = _jnp_inputs(bn, data)
    n = bn.n
    # 3 parents of arity >= 2 -> q0 >= 8; max_q=16 overflows most candidates
    adj = np.zeros((n, n), dtype=np.int8)
    adj[[0, 5, 7], 2] = 1
    kw = dict(ess=10.0, max_q=16, r_max=int(bn.arities.max()))
    D_seg = np.asarray(bdeu.insert_deltas(
        dj, aj, jnp.asarray(adj), counts_impl="segment", **kw))
    D_fus = np.asarray(bdeu.insert_deltas(
        dj, aj, jnp.asarray(adj), counts_impl="fused", **kw))
    assert np.isneginf(D_fus[:, 2]).any()       # the guard actually fires
    assert np.array_equal(np.isneginf(D_seg), np.isneginf(D_fus))


@pytest.mark.parametrize("impl", FUSED_IMPLS)
def test_fused_subset_column_matches_segment(case, impl):
    """Restricted-subset (pid_table) columns agree with the loop engine at
    EVERY entry: the sweep engine masks illegal toggles (self-pads, pids
    already in Pa_y) to -inf under both backends, so no caller-side masking
    is needed (regression for the old fused-path convention mismatch)."""
    bn, data = case
    dj, aj = _jnp_inputs(bn, data)
    n = bn.n
    adj = np.zeros((n, n), dtype=np.int8)
    adj[0, 3] = 1
    y = 3
    # pids include an existing parent (0) and self-padded tail entries
    pids = np.array([1, 0, 2, 5, 7, 9, y, y], dtype=np.int32)
    kw = dict(kind="insert", y=y, pids=jnp.asarray(pids), ess=10.0,
              max_q=256, r_max=int(bn.arities.max()))
    col_seg = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                               counts_impl="segment", **kw))
    col_fus = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                               counts_impl=impl, **kw))
    illegal = (pids == y) | (adj[pids, y] > 0)
    assert np.all(np.isneginf(col_seg[illegal]))
    assert np.all(np.isneginf(col_fus[illegal]))
    assert np.allclose(col_seg[~illegal], col_fus[~illegal],
                       rtol=1e-4, atol=2e-3)


def test_fused_incremental_column_matches_segment(case):
    """The incremental column-rescoring hot path (sweep with y, no pids)
    agrees across engines at every entry (illegal ones are -inf in both)."""
    bn, data = case
    dj, aj = _jnp_inputs(bn, data)
    n = bn.n
    adj = np.zeros((n, n), dtype=np.int8)
    adj[4, 1] = 1
    y = 1
    kw = dict(kind="insert", y=y, ess=10.0, max_q=256,
              r_max=int(bn.arities.max()))
    col_seg = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                               counts_impl="segment", **kw))
    col_fus = np.asarray(sweep(dj, aj, jnp.asarray(adj),
                               counts_impl="fused", **kw))
    illegal = np.zeros(n, dtype=bool)
    illegal[y] = True
    illegal[adj[:, y] > 0] = True
    assert np.all(np.isneginf(col_seg[illegal]))
    assert np.all(np.isneginf(col_fus[illegal]))
    assert np.allclose(col_seg[~illegal], col_fus[~illegal],
                       rtol=1e-4, atol=2e-3)


def test_ges_jit_trajectory_identity_across_impls(case):
    """The whole compiled FES+BES search must take the SAME greedy trajectory
    (same graph, same score) under the fused and loop engines."""
    bn, data = case
    dj, aj = _jnp_inputs(bn, data)
    n = bn.n
    z = jnp.zeros((n, n), jnp.int8)
    o = jnp.ones((n, n), jnp.int8)
    ref_adj = ref_score = None
    for impl in ["segment", "fused"]:
        cfg = GESConfig(max_q=256, counts_impl=impl)
        adj, score, _, _ = ges_jit(dj, aj, z, o, config=cfg)
        if ref_adj is None:
            ref_adj, ref_score = np.asarray(adj), float(score)
        else:
            assert np.array_equal(np.asarray(adj), ref_adj)
            assert abs(float(score) - ref_score) <= 1e-6 * abs(ref_score)


def test_ges_host_trajectory_identity_across_impls(case):
    """ges_host (the cGES host engine path) with fused columns reproduces the
    segment-engine trajectory and the host-oracle final score."""
    bn, data = case
    res_s = ges_host(data, bn.arities,
                     config=GESConfig(max_q=256, counts_impl="segment"))
    res_f = ges_host(data, bn.arities,
                     config=GESConfig(max_q=256, counts_impl="fused"))
    assert np.array_equal(res_s.adj, res_f.adj)
    assert np.isclose(res_s.score, res_f.score, rtol=1e-9)
