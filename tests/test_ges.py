"""GES / fGES / cGES behaviour."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import GESConfig, bdeu, cges, fges_host, ges_host, ges_jit
from repro.core.cges import edge_add_limit
from repro.core.dag import is_dag_np, smhd_np
from repro.data.bn import forward_sample, random_bn

CFG = GESConfig(max_q=256)


@pytest.fixture(scope="module")
def case():
    rng = np.random.default_rng(11)
    bn = random_bn(rng, n=12, n_edges=14, max_parents=3)
    data = forward_sample(bn, 1500, rng)
    return bn, data


def test_ges_monotone_and_dag(case):
    bn, data = case
    res = ges_host(data, bn.arities, config=CFG)
    assert is_dag_np(res.adj)
    empty = bdeu.graph_score_np(data, bn.arities,
                                np.zeros_like(res.adj))
    assert res.score > empty


def test_ges_respects_allowed_mask(case):
    bn, data = case
    n = bn.n
    allowed = np.zeros((n, n), dtype=bool)
    allowed[0, 1] = allowed[1, 2] = allowed[3, 4] = True
    res = ges_host(data, bn.arities, allowed=allowed, config=CFG)
    assert np.all(allowed | ~res.adj.astype(bool))  # adj subset of allowed


def test_ges_add_limit(case):
    bn, data = case
    res = ges_host(data, bn.arities, add_limit=3, config=CFG)
    assert res.n_inserts <= 3


def test_ges_jit_matches_host(case):
    bn, data = case
    n = bn.n
    res_h = ges_host(data, bn.arities, config=CFG)
    adj_j, score_j, n_ins, n_del = ges_jit(
        jnp.asarray(data.astype(np.int32)),
        jnp.asarray(bn.arities.astype(np.int32)),
        jnp.zeros((n, n), jnp.int8), jnp.ones((n, n), jnp.int8),
        config=CFG)
    # identical greedy trajectory -> identical graph
    assert np.array_equal(np.asarray(adj_j), res_h.adj)
    assert np.isclose(float(score_j), res_h.score, rtol=1e-5, atol=0.5)


@pytest.mark.parametrize("impl", ["segment", "fused", "fused_pallas"])
def test_ges_jit_pid_table_trajectory_identity(case, impl):
    """The compiled W-wide program (pid_table threaded through the
    while_loop) takes the IDENTICAL greedy trajectory as the old
    full-n-masked compiled path and the host driver, on a restricted
    allowed mask — under every backend."""
    from repro.core import pid_table_from_allowed

    bn, data = case
    n = bn.n
    rng = np.random.default_rng(5)
    allowed = rng.random((n, n)) < 0.5
    np.fill_diagonal(allowed, False)
    allowed[:, 3] = False                  # empty E_i column (all self-pads)
    tbl = jnp.asarray(pid_table_from_allowed(allowed))
    assert tbl.shape[1] < n                # genuinely restricted (W < n)
    cfg = GESConfig(max_q=256, counts_impl=impl)
    dj = jnp.asarray(data.astype(np.int32))
    aj = jnp.asarray(bn.arities.astype(np.int32))
    zeros = jnp.zeros((n, n), jnp.int8)
    mask_j = jnp.asarray(allowed.astype(np.int8))
    adj_f, score_f, _, _ = ges_jit(dj, aj, zeros, mask_j, config=cfg)
    adj_w, score_w, _, _ = ges_jit(dj, aj, zeros, mask_j, config=cfg,
                                   pid_table=tbl)
    assert np.array_equal(np.asarray(adj_f), np.asarray(adj_w))
    assert np.isclose(float(score_f), float(score_w), rtol=1e-6)
    res_h = ges_host(data, bn.arities, allowed=allowed, config=cfg)
    assert np.array_equal(res_h.adj, np.asarray(adj_w))
    # the restriction is honoured: no edge outside the allowed mask
    assert np.all(allowed | ~np.asarray(adj_w).astype(bool))


def test_ges_recovers_chain():
    """0->1->2 with strong CPTs: GES must recover the Markov equivalence class."""
    rng = np.random.default_rng(0)
    m = 4000
    x0 = rng.integers(0, 2, m)
    x1 = (x0 ^ (rng.random(m) < 0.05)).astype(int)
    x2 = (x1 ^ (rng.random(m) < 0.05)).astype(int)
    data = np.stack([x0, x1, x2], 1).astype(np.int32)
    ar = np.array([2, 2, 2])
    res = ges_host(data, ar, config=CFG)
    truth = np.zeros((3, 3), dtype=np.int8)
    truth[0, 1] = truth[1, 2] = 1
    assert smhd_np(res.adj, truth) == 0


def test_fges_runs_and_scores(case):
    bn, data = case
    res = fges_host(data, bn.arities, config=CFG)
    assert is_dag_np(res.adj)
    assert np.isfinite(res.score)


def test_edge_add_limit_formula():
    # (10 / k) * sqrt(n), paper section 3
    assert edge_add_limit(100, 2) == 50
    assert edge_add_limit(100, 8) == round(10 / 8 * 10)


@pytest.mark.parametrize("limit", [True, False])
def test_cges_end_to_end(case, limit):
    bn, data = case
    res = cges(data, bn.arities, k=2, limit=limit, config=CFG)
    assert is_dag_np(res.adj)
    # paper claim: cGES final quality comparable to GES (fine-tune pass
    # guarantees >= its ring input; compare against GES within tolerance)
    ref = ges_host(data, bn.arities, config=CFG)
    assert res.score >= ref.score - abs(ref.score) * 0.02
    assert res.rounds >= 1
    assert res.edge_masks.shape[0] == 2


def test_cges_engine_jax_close_to_host(case):
    bn, data = case
    res_j = cges(data, bn.arities, k=2, limit=True, config=CFG, engine="jax")
    res_h = cges(data, bn.arities, k=2, limit=True, config=CFG, engine="host")
    assert is_dag_np(res_j.adj)
    assert np.isclose(res_j.score, res_h.score,
                      rtol=5e-3, atol=abs(res_h.score) * 5e-3)


def test_score_cache_hits(case):
    from repro.core import ScoreCache
    bn, data = case
    cache = ScoreCache()
    ges_host(data, bn.arities, config=CFG, cache=cache)
    before = cache.misses
    ges_host(data, bn.arities, config=CFG, cache=cache)  # identical run
    assert cache.hits >= before  # second run served from cache


def test_counts_impl_env_honoured_after_import(monkeypatch):
    """REPRO_COUNTS_IMPL set AFTER ``import repro`` must be honoured: the
    GESConfig default is a default_factory (evaluated per instantiation),
    not a plain dataclass default (bound once at class creation)."""
    monkeypatch.setenv("REPRO_COUNTS_IMPL", "fused")
    assert GESConfig().counts_impl == "fused"
    monkeypatch.setenv("REPRO_COUNTS_IMPL", "fused_pallas")
    assert GESConfig().counts_impl == "fused_pallas"
    monkeypatch.delenv("REPRO_COUNTS_IMPL")
    assert GESConfig().counts_impl == "segment"
    # a typo'd env value still fails loudly at construction
    monkeypatch.setenv("REPRO_COUNTS_IMPL", "fuesd")
    with pytest.raises(ValueError, match="unknown counts_impl"):
        GESConfig()
