"""Per-kernel allclose vs pure-jnp oracle, swept over shapes/dtypes
(hypothesis + parametrized grids).  Pallas kernels run in interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.bdeu_count import contingency_counts, contingency_counts_ref
from repro.kernels.bdeu_sweep import sweep_counts
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref


# ---------------------------------------------------------------------------
# bdeu_count
# ---------------------------------------------------------------------------

@given(st.integers(0, 10**6), st.integers(1, 700), st.integers(2, 7),
       st.integers(4, 90))
@settings(max_examples=20, deadline=None)
def test_bdeu_count_matches_ref(seed, m, r, q):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    cfg = jax.random.randint(k1, (m,), 0, q, dtype=jnp.int32)
    child = jax.random.randint(k2, (m,), 0, r, dtype=jnp.int32)
    got = contingency_counts(cfg, child, max_q=q, r_max=r, tile_m=128)
    want = contingency_counts_ref(cfg, child, max_q=q, r_pad=r)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_bdeu_count_total_mass():
    cfg = jnp.zeros((1000,), jnp.int32)
    child = jnp.ones((1000,), jnp.int32)
    counts = contingency_counts(cfg, child, max_q=4, r_max=3)
    assert float(counts.sum()) == 1000.0
    assert float(counts[0, 1]) == 1000.0


# ---------------------------------------------------------------------------
# bdeu_sweep (fused all-candidate contraction)
# ---------------------------------------------------------------------------

@given(st.integers(0, 10**6), st.integers(1, 500), st.integers(2, 5),
       st.integers(4, 60), st.integers(1, 50))
@settings(max_examples=15, deadline=None)
def test_bdeu_sweep_matches_ref(seed, m, r, q, n):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    cfg = jax.random.randint(k1, (m,), 0, q, dtype=jnp.int32)
    child = jax.random.randint(k2, (m,), 0, r, dtype=jnp.int32)
    data = jax.random.randint(k3, (m, n), 0, r, dtype=jnp.int32)
    got = sweep_counts(cfg, child, data, max_q=q, r_max=r,
                       tile_m=128, tile_n=16)
    want = sweep_counts(cfg, child, data, max_q=q, r_max=r, use_ref=True)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_bdeu_sweep_total_mass_and_blocks():
    """Every (b, x) block sums to the number of instances with child=b; the
    whole tensor sums to m * n (each instance counted once per variable)."""
    m, n, q, r = 640, 5, 8, 3
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    cfg = jax.random.randint(k1, (m,), 0, q, dtype=jnp.int32)
    child = jax.random.randint(k2, (m,), 0, r, dtype=jnp.int32)
    data = jax.random.randint(k3, (m, n), 0, r, dtype=jnp.int32)
    counts = np.asarray(sweep_counts(cfg, child, data, max_q=q, r_max=r))
    assert counts.shape == (r, q, n * r)
    assert float(counts.sum()) == float(m * n)
    child_np = np.asarray(child)
    per_b = counts.reshape(r, q, n, r).sum(axis=(1, 3))  # (b, x)
    for b in range(r):
        assert np.all(per_b[b] == np.sum(child_np == b))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,t,d", [
    (1, 4, 4, 128, 64),     # MHA, exact blocks
    (2, 8, 2, 256, 64),     # GQA 4:1
    (1, 4, 1, 200, 32),     # MQA, ragged seq (padding path)
    (1, 16, 8, 384, 128),   # GQA 2:1, bigger head_dim
])
def test_flash_attention_matches_ref(b, hq, hkv, t, d, dtype):
    key = jax.random.PRNGKey(hq * t + d)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, t, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, t, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, t, d), dtype)
    got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    want = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@given(st.integers(0, 10**6))
@settings(max_examples=8, deadline=None)
def test_flash_attention_random_shapes(seed):
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 3))
    hkv = int(rng.choice([1, 2, 4]))
    group = int(rng.choice([1, 2, 4]))
    t = int(rng.integers(16, 300))
    d = int(rng.choice([32, 64]))
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hkv * group, t, d))
    k = jax.random.normal(ks[1], (b, hkv, t, d))
    v = jax.random.normal(ks[2], (b, hkv, t, d))
    got = flash_attention(q, k, v, causal=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,t,p,n,chunk", [
    (1, 2, 128, 32, 16, 64),
    (2, 4, 256, 64, 32, 128),
    (1, 1, 100, 16, 8, 32),     # ragged (padding path)
])
def test_ssd_scan_matches_ref(b, h, t, p, n, chunk, dtype):
    key = jax.random.PRNGKey(t + p)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, h, t, p), dtype)
    a = -jax.nn.softplus(jax.random.normal(ks[1], (b, h, t)))
    bm = jax.random.normal(ks[2], (b, h, t, n), dtype) * 0.3
    cm = jax.random.normal(ks[3], (b, h, t, n), dtype) * 0.3
    got = ssd_scan(x, a, bm, cm, chunk=chunk)
    want = ssd_scan_ref(x, a, bm, cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@given(st.integers(0, 10**6))
@settings(max_examples=6, deadline=None)
def test_ssd_scan_random_shapes(seed):
    rng = np.random.default_rng(seed)
    b, h = int(rng.integers(1, 3)), int(rng.integers(1, 4))
    t = int(rng.integers(10, 200))
    p = int(rng.choice([16, 32]))
    n = int(rng.choice([8, 16]))
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, h, t, p))
    a = -jax.nn.softplus(jax.random.normal(ks[1], (b, h, t)))
    bm = jax.random.normal(ks[2], (b, h, t, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, h, t, n)) * 0.3
    got = ssd_scan(x, a, bm, cm, chunk=64)
    want = ssd_scan_ref(x, a, bm, cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_ssd_chunk_stitching_matches_single_chunk():
    """Cross-chunk state passing: chunk=T vs chunk=T/4 must agree exactly."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    b, h, t, p, n = 1, 2, 128, 16, 8
    x = jax.random.normal(ks[0], (b, h, t, p))
    a = -jax.nn.softplus(jax.random.normal(ks[1], (b, h, t)))
    bm = jax.random.normal(ks[2], (b, h, t, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, h, t, n)) * 0.3
    big = ssd_scan(x, a, bm, cm, chunk=128)
    small = ssd_scan(x, a, bm, cm, chunk=32)
    np.testing.assert_allclose(np.asarray(big), np.asarray(small),
                               rtol=2e-4, atol=2e-4)
