import os
import sys

# Tests must see 1 CPU device (the dry-run alone forces 512 — never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_bn():
    from repro.data.bn import random_bn
    return random_bn(np.random.default_rng(7), n=10, n_edges=12, max_parents=3)


@pytest.fixture(scope="session")
def small_data(small_bn):
    from repro.data.bn import forward_sample
    return forward_sample(small_bn, 1200, np.random.default_rng(3))
