"""Substrate: checkpointing, data pipeline, optimizer, compression."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.tokens import DataConfig, TokenPipeline
from repro.training import AdamWConfig, adamw_update, init_opt_state
from repro.training.checkpoint import CheckpointManager
from repro.training.compress import (compress_with_feedback, dequantize_int8,
                                     quantize_int8)


# -- data pipeline ----------------------------------------------------------

def test_pipeline_deterministic():
    pipe = TokenPipeline(DataConfig(vocab=100, seq_len=16, global_batch=4))
    a = pipe.batch_at(7)
    b = pipe.batch_at(7)
    assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = pipe.batch_at(8)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_pipeline_labels_are_shifted_tokens():
    pipe = TokenPipeline(DataConfig(vocab=50, seq_len=12, global_batch=2))
    b = pipe.batch_at(0)
    assert b["tokens"].shape == (2, 12)
    assert b["labels"].shape == (2, 12)
    # tokens/labels come from one (T+1) stream: labels[t] == tokens[t+1]
    assert np.array_equal(np.asarray(b["tokens"][:, 1:]),
                          np.asarray(b["labels"][:, :-1]))


def test_pipeline_shard_of_partitions_batch():
    pipe = TokenPipeline(DataConfig(vocab=50, seq_len=8, global_batch=8))
    full = pipe.batch_at(3)
    s0 = pipe.shard_of(3, 0, 4)
    s1 = pipe.shard_of(3, 1, 4)
    assert s0["tokens"].shape == (2, 8)
    assert np.array_equal(np.asarray(s0["tokens"]),
                          np.asarray(full["tokens"][0::4]))
    assert np.array_equal(np.asarray(s1["tokens"]),
                          np.asarray(full["tokens"][1::4]))


# -- optimizer ----------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, params, g, state)
    assert float(loss(params)) < l0 * 0.05


def test_adamw_grad_clip_caps_update():
    cfg = AdamWConfig(lr=1.0, warmup_steps=0, grad_clip=1e-3,
                      weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)
    g = {"w": jnp.asarray([1e6, -1e6, 1e6])}
    _, _, metrics = adamw_update(cfg, params, g, state)
    assert float(metrics["grad_norm"]) > 1e5  # raw norm reported


# -- checkpoint ---------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "nest": {"b": jnp.ones((4,), jnp.bfloat16)}}
    opt = init_opt_state(params)
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    mgr.save(5, params, opt, {"loss": 1.0})
    mgr.save(10, params, opt, {"loss": 0.5})
    assert mgr.all_steps() == [5, 10]
    p2, o2, man = mgr.restore(10, params, opt)
    assert man["step"] == 10
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(x, np.float32),
                              np.asarray(y, np.float32))
        assert x.dtype == y.dtype


def test_checkpoint_prunes_old(tmp_path):
    params = {"a": jnp.zeros(2)}
    opt = init_opt_state(params)
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, params, opt)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_atomic_no_partial(tmp_path):
    """A stray .tmp dir (killed writer) must be invisible to latest()."""
    params = {"a": jnp.zeros(2)}
    opt = init_opt_state(params)
    mgr = CheckpointManager(str(tmp_path), keep_last=3)
    mgr.save(1, params, opt)
    os.makedirs(os.path.join(str(tmp_path), "step_0000000002.tmp"))
    assert mgr.latest() == 1


def test_train_resume_replays_identically(tmp_path):
    """kill/restart determinism: train 6 steps straight == 3 + resume 3."""
    from repro.configs import get_smoke_config
    from repro.models import transformer
    from repro.training import build_train_step

    cfg = get_smoke_config("mamba2_130m")
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=16,
                                    global_batch=2))
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    step_fn = jax.jit(build_train_step(cfg, ocfg))

    def run(params, opt, lo, hi):
        for s in range(lo, hi):
            params, opt, m = step_fn(params, opt, pipe.batch_at(s))
        return params, opt, m

    key = jax.random.PRNGKey(0)
    p0 = transformer.init_params(key, cfg)
    o0 = init_opt_state(p0)
    pA, oA, mA = run(p0, o0, 0, 6)

    pB, oB, _ = run(p0, o0, 0, 3)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, pB, oB)
    pB2, oB2, _ = mgr.restore(3, pB, oB)
    pB3, oB3, mB = run(pB2, oB2, 3, 6)
    assert np.isclose(float(mA["loss"]), float(mB["loss"]), rtol=1e-5)


# -- compression --------------------------------------------------------------

def test_quantize_int8_bounded_error():
    x = jnp.asarray(np.random.default_rng(0).normal(size=512) * 3)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_is_lossless_over_time():
    """sum of transmitted values converges to sum of true gradients."""
    rng = np.random.default_rng(1)
    err = jnp.zeros(64)
    sent_total = np.zeros(64)
    true_total = np.zeros(64)
    for _ in range(200):
        g = jnp.asarray(rng.normal(size=64))
        q, scale, err = compress_with_feedback(g, err)
        sent_total += np.asarray(dequantize_int8(q, scale))
        true_total += np.asarray(g)
    # residual bounded by one quantization step, not growing with T
    assert np.abs(sent_total - true_total).max() < 0.5


def test_ring_allreduce_matches_psum():
    """ring_allreduce over a k-device mesh == plain sum (subprocess: needs
    multiple devices)."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        import sys
        sys.path.insert(0, "src")
        from repro.core.ring import _shard_map_compat
        from repro.training.compress import ring_allreduce
        mesh = Mesh(np.array(jax.devices()[:4]), ("dp",))
        x = jnp.arange(4 * 6, dtype=jnp.float32).reshape(4, 6)
        def body(xl):
            return ring_allreduce(xl[0], "dp", 4)[None]
        f = jax.jit(_shard_map_compat(body, mesh=mesh,
                                      in_specs=(P("dp", None),),
                                      out_specs=P("dp", None)))
        out = np.asarray(f(x))
        want = np.broadcast_to(x.sum(0), (4, 6))
        assert np.allclose(out, want), (out, want)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "OK" in r.stdout, r.stderr[-2000:]
