"""Edge partitioning: disjoint cover, balance, elastic re-merge."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import partition


def _sim(seed, n=12):
    rng = np.random.default_rng(seed)
    s = rng.normal(size=(n, n))
    s = (s + s.T) / 2
    np.fill_diagonal(s, 0)
    return s


@given(st.integers(0, 10_000), st.integers(2, 6))
@settings(max_examples=25, deadline=None)
def test_clusters_partition_variables(seed, k):
    sim = _sim(seed)
    clusters = partition.variable_clusters(sim, k)
    assert len(clusters) == k
    flat = sorted(v for c in clusters for v in c)
    assert flat == list(range(sim.shape[0]))


@given(st.integers(0, 10_000), st.integers(2, 5))
@settings(max_examples=25, deadline=None)
def test_edge_subsets_disjoint_cover(seed, k):
    n = 10
    clusters = partition.variable_clusters(_sim(seed, n), k)
    masks = partition.edge_subsets(clusters, n)
    total = masks.sum(axis=0)
    off_diag = ~np.eye(n, dtype=bool)
    assert np.all(total[off_diag] == 1)      # every edge in exactly one subset
    assert np.all(total[~off_diag] == 0)


def test_edge_subsets_balanced():
    n = 16
    clusters = [[i] for i in range(n)][:4]
    clusters = [[0, 1, 2, 3], [4, 5, 6, 7], [8, 9, 10, 11], [12, 13, 14, 15]]
    masks = partition.edge_subsets(clusters, n)
    sizes = masks.sum(axis=(1, 2))
    assert sizes.max() - sizes.min() <= 0.25 * sizes.max()


@given(st.integers(0, 10_000), st.integers(3, 5))
@settings(max_examples=15, deadline=None)
def test_remerge_failed_preserves_cover(seed, k):
    n = 9
    clusters = partition.variable_clusters(_sim(seed, n), k)
    masks = partition.edge_subsets(clusters, n)
    failed = seed % k
    out = partition.remerge_failed(masks, failed)
    assert out.shape[0] == k - 1
    off = ~np.eye(n, dtype=bool)
    assert np.all(out.sum(axis=0)[off] == 1)


def test_partition_edges_end_to_end(small_data, small_bn):
    masks = partition.partition_edges(small_data, small_bn.arities, 3)
    n = small_bn.n
    off = ~np.eye(n, dtype=bool)
    assert masks.shape == (3, n, n)
    assert np.all(masks.sum(axis=0)[off] == 1)
