"""Edge partitioning: disjoint cover, balance, elastic re-merge."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import partition


def _sim(seed, n=12):
    rng = np.random.default_rng(seed)
    s = rng.normal(size=(n, n))
    s = (s + s.T) / 2
    np.fill_diagonal(s, 0)
    return s


@given(st.integers(0, 10_000), st.integers(2, 6))
@settings(max_examples=25, deadline=None)
def test_clusters_partition_variables(seed, k):
    sim = _sim(seed)
    clusters = partition.variable_clusters(sim, k)
    assert len(clusters) == k
    flat = sorted(v for c in clusters for v in c)
    assert flat == list(range(sim.shape[0]))


@given(st.integers(0, 10_000), st.integers(2, 5))
@settings(max_examples=25, deadline=None)
def test_edge_subsets_disjoint_cover(seed, k):
    n = 10
    clusters = partition.variable_clusters(_sim(seed, n), k)
    masks = partition.edge_subsets(clusters, n)
    total = masks.sum(axis=0)
    off_diag = ~np.eye(n, dtype=bool)
    assert np.all(total[off_diag] == 1)      # every edge in exactly one subset
    assert np.all(total[~off_diag] == 0)


def test_edge_subsets_balanced():
    n = 16
    clusters = [[i] for i in range(n)][:4]
    clusters = [[0, 1, 2, 3], [4, 5, 6, 7], [8, 9, 10, 11], [12, 13, 14, 15]]
    masks = partition.edge_subsets(clusters, n)
    sizes = masks.sum(axis=(1, 2))
    assert sizes.max() - sizes.min() <= 0.25 * sizes.max()


@given(st.integers(0, 10_000), st.integers(3, 5))
@settings(max_examples=15, deadline=None)
def test_remerge_failed_preserves_cover(seed, k):
    n = 9
    clusters = partition.variable_clusters(_sim(seed, n), k)
    masks = partition.edge_subsets(clusters, n)
    failed = seed % k
    out = partition.remerge_failed(masks, failed)
    assert out.shape[0] == k - 1
    off = ~np.eye(n, dtype=bool)
    assert np.all(out.sum(axis=0)[off] == 1)


def test_partition_edges_end_to_end(small_data, small_bn):
    masks = partition.partition_edges(small_data, small_bn.arities, 3)
    n = small_bn.n
    off = ~np.eye(n, dtype=bool)
    assert masks.shape == (3, n, n)
    assert np.all(masks.sum(axis=0)[off] == 1)


def _edge_subsets_loop(clusters, n):
    """The pre-vectorization reference: sequential greedy smallest-subset
    assignment of cross pairs (kept as the mask-identity oracle)."""
    k = len(clusters)
    masks = np.zeros((k, n, n), dtype=bool)
    cluster_of = np.empty(n, dtype=np.int64)
    for ci, members in enumerate(clusters):
        for v in members:
            cluster_of[v] = ci
        for x in members:
            for y in members:
                if x != y:
                    masks[ci, x, y] = True
    sizes = masks.sum(axis=(1, 2))
    for x in range(n):
        for y in range(x + 1, n):
            if cluster_of[x] != cluster_of[y]:
                tgt = int(np.argmin(sizes))
                masks[tgt, x, y] = True
                masks[tgt, y, x] = True
                sizes[tgt] += 2
    return masks


@given(st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_edge_subsets_mask_identical_to_loop_reference(seed):
    """The vectorized sorted-token-merge assignment reproduces the
    sequential greedy loop mask-for-mask (same targets, same order)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 28))
    k = int(rng.integers(1, min(n, 6) + 1))
    perm = rng.permutation(n)
    cuts = (np.sort(rng.choice(np.arange(1, n), size=k - 1, replace=False))
            if k > 1 else [])
    clusters = [list(c) for c in np.split(perm, cuts)]
    got = partition.edge_subsets(clusters, n)
    want = _edge_subsets_loop(clusters, n)
    assert np.array_equal(got, want), (seed, n, k)


def test_edge_subsets_empty():
    assert partition.edge_subsets([], 0).shape == (0, 0, 0)
