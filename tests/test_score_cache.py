"""Persistent family-score cache (core/score_cache + driver wiring):
exact-key probe/insert round-trips, prioritized eviction, hit-path
semantics of ``lookup_or_compute``, the ``REPRO_FAMILY_CACHE`` call-time
env default, and cached-vs-uncached trajectory pins for ges_host,
ges_jit (full-n and pid_table-restricted), cges (both engines) and the
compiled ring (subprocess, multi-device).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import DeviceFamilyCache, GESConfig, cges, ges_host, ges_jit
from repro.core import score_cache as sc

from _hypothesis_compat import given, settings, st

N_VARS = 12


def _mask_from_int(bits: int) -> jnp.ndarray:
    return jnp.asarray([(bits >> i) & 1 for i in range(N_VARS)], jnp.int32)


def _key_tuple(seed: int):
    return (seed % 2,                       # kind
            (seed // 2) % N_VARS,           # child
            seed % (1 << N_VARS),           # parent mask bits
            (seed * 31) % 97)               # scope


def test_probe_insert_roundtrip():
    cache = sc.init(N_VARS, width=N_VARS, capacity=64)
    col = jnp.arange(N_VARS, dtype=jnp.float32) - 3.0
    mask = _mask_from_int(0b1010)
    hit, _, cache = sc.probe(cache, sc.KIND_INSERT, 2, mask, 0)
    assert not bool(hit)
    cache = sc.insert(cache, sc.KIND_INSERT, 2, mask, 0, col)
    hit, got, cache = sc.probe(cache, sc.KIND_INSERT, 2, mask, 0)
    assert bool(hit)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(col))
    # every key word must participate in matching: perturb each component
    for kind, child, scope in [(sc.KIND_DELETE, 2, 0), (sc.KIND_INSERT, 3, 0),
                               (sc.KIND_INSERT, 2, 1)]:
        h, _, cache = sc.probe(cache, kind, child, mask, scope)
        assert not bool(h), (kind, child, scope)
    h, _, cache = sc.probe(cache, sc.KIND_INSERT, 2, _mask_from_int(0b1011), 0)
    assert not bool(h)
    st_ = sc.stats(cache)
    assert st_["hits"] == 1 and st_["misses"] == 1 and st_["occupied"] == 1


@settings(max_examples=24, deadline=None)
@given(st.integers(min_value=0, max_value=10**6),
       st.integers(min_value=0, max_value=10**6))
def test_key_packing_exact(a, b):
    """Packed keys are equal word-for-word IFF the (kind, child, mask,
    scope) tuples are equal — the no-collision contract that makes cached
    trajectories bitwise-identical."""
    ta, tb = _key_tuple(a), _key_tuple(b)
    ka = sc._pack_key(ta[0], ta[1], _mask_from_int(ta[2]), ta[3])
    kb = sc._pack_key(tb[0], tb[1], _mask_from_int(tb[2]), tb[3])
    assert bool(jnp.all(ka == kb)) == (ta == tb)


def test_eviction_prefers_low_priority_and_probe_refreshes():
    """capacity == WAYS -> a single set: inserting WAYS+1 keys evicts the
    min-priority way, and a probe hit refreshes recency so the re-touched
    entry survives while the stalest one is evicted."""
    cache = sc.init(N_VARS, width=4, capacity=sc.WAYS)
    neg = jnp.full((4,), -jnp.inf, jnp.float32)   # sigmoid gain bonus = 0
    for i in range(sc.WAYS):
        cache = sc.insert(cache, 0, i, _mask_from_int(0), 0, neg)
    assert sc.stats(cache)["occupied"] == sc.WAYS
    # refresh key child=0 (inserted first, currently stalest)
    hit, _, cache = sc.probe(cache, 0, 0, _mask_from_int(0), 0)
    assert bool(hit)
    cache = sc.insert(cache, 0, sc.WAYS, _mask_from_int(0), 0, neg)
    assert sc.stats(cache)["occupied"] == sc.WAYS
    hit0, _, cache = sc.probe(cache, 0, 0, _mask_from_int(0), 0)
    assert bool(hit0)                   # refreshed -> survived
    hit1, _, cache = sc.probe(cache, 0, 1, _mask_from_int(0), 0)
    assert not bool(hit1)               # stalest un-refreshed way evicted


def test_positive_gain_column_outranks_exhausted_column():
    """The PER-flavoured bonus: at the same access step, a column that
    still contains a positive score delta gets strictly higher eviction
    priority than one whose every toggle is masked/non-improving."""
    step = jnp.int32(7)
    improving = sc._priority(step, jnp.asarray([-1.0, 0.5], jnp.float32))
    exhausted = sc._priority(step, jnp.asarray([-jnp.inf, -2.0], jnp.float32))
    assert float(improving) > float(exhausted)
    assert float(improving) - float(exhausted) <= sc.GAIN_WEIGHT + 1e-6


def test_lookup_or_compute_hit_returns_cached_column():
    cache = sc.init(N_VARS, width=3, capacity=32)
    mask = _mask_from_int(0b11)
    col0 = jnp.asarray([1.0, -2.0, 0.5], jnp.float32)
    got0, cache = sc.lookup_or_compute(cache, 0, 1, mask, 0, lambda: col0)
    np.testing.assert_array_equal(np.asarray(got0), np.asarray(col0))
    # same key, different compute closure: the CACHED column must win
    decoy = jnp.asarray([9.0, 9.0, 9.0], jnp.float32)
    got1, cache = sc.lookup_or_compute(cache, 0, 1, mask, 0, lambda: decoy)
    np.testing.assert_array_equal(np.asarray(got1), np.asarray(col0))
    st_ = sc.stats(cache)
    assert st_["hits"] == 1 and st_["misses"] == 1


def test_family_cache_env_default_read_at_call_time(monkeypatch):
    """GESConfig.family_cache defaults from REPRO_FAMILY_CACHE at
    INSTANTIATION time (default_factory), so the CI leg's env flip works
    even when the var is set after ``import repro``."""
    monkeypatch.delenv("REPRO_FAMILY_CACHE", raising=False)
    assert GESConfig().family_cache is False
    monkeypatch.setenv("REPRO_FAMILY_CACHE", "1")
    assert GESConfig().family_cache is True
    monkeypatch.setenv("REPRO_FAMILY_CACHE", "0")
    assert GESConfig().family_cache is False


def test_cache_capacity_validation():
    with pytest.raises(ValueError):
        GESConfig(cache_capacity=0)


def _dataset(seed=5, n=9, m=240):
    rng = np.random.default_rng(seed)
    arities = rng.integers(2, 4, size=n).astype(np.int64)
    data = np.stack([rng.integers(0, a, size=m) for a in arities], 1)
    return data.astype(np.int64), arities


def test_ges_host_cached_trajectory_identical():
    data, arities = _dataset()
    n = arities.size
    # family_cache pinned False: under the REPRO_FAMILY_CACHE=1 CI leg the
    # env default would otherwise silently cache the "uncached" baseline
    base = ges_host(data, arities,
                    config=GESConfig(max_q=64, counts_impl="fused",
                                     family_cache=False))
    fc = DeviceFamilyCache(n, capacity=512)
    r1 = ges_host(data, arities,
                  config=GESConfig(max_q=64, counts_impl="fused",
                                   family_cache=True, cache_capacity=512),
                  family_cache=fc)
    assert np.array_equal(base.adj, r1.adj)
    assert base.score == r1.score
    st1 = fc.stats()
    assert st1["misses"] > 0
    # second run through the SAME handle: warm, hit-dominated, identical
    r2 = ges_host(data, arities,
                  config=GESConfig(max_q=64, counts_impl="fused",
                                   family_cache=True, cache_capacity=512),
                  family_cache=fc)
    assert np.array_equal(base.adj, r2.adj) and base.score == r2.score
    st2 = fc.stats()
    assert st2["hits"] > st1["hits"]
    assert st2["misses"] == st1["misses"]    # nothing new to compute


def test_ges_host_rejects_mismatched_cache_width():
    data, arities = _dataset()
    with pytest.raises(ValueError, match="family_cache"):
        ges_host(data, arities,
                 config=GESConfig(max_q=64, family_cache=True),
                 family_cache=DeviceFamilyCache(arities.size + 1))


@pytest.mark.parametrize("incremental", [True, False])
def test_ges_jit_cached_trajectory_identical(incremental):
    """Compiled engine: cache on/off bitwise-identical (adjacency AND
    score), warm restart via the returned cache pytree is hit-dominated."""
    data, arities = _dataset(seed=7, n=8, m=160)
    n = arities.size
    allowed = ~np.eye(n, dtype=bool)
    init = np.zeros((n, n), np.int8)
    kw = dict(config=GESConfig(max_q=64, counts_impl="segment",
                               incremental=incremental, family_cache=False))
    a0, s0, _, _ = ges_jit(data, arities, init, allowed, **kw)
    cfg_c = GESConfig(max_q=64, counts_impl="segment",
                      incremental=incremental, family_cache=True,
                      cache_capacity=256)
    a1, s1, _, _, cache = ges_jit(data, arities, init, allowed,
                                  config=cfg_c, return_cache=True)
    assert np.array_equal(np.asarray(a0), np.asarray(a1))
    assert float(s0) == float(s1)
    st1 = sc.stats(cache)
    a2, s2, _, _, cache2 = ges_jit(data, arities, init, allowed,
                                   config=cfg_c, cache=cache,
                                   return_cache=True)
    assert np.array_equal(np.asarray(a0), np.asarray(a2))
    assert float(s0) == float(s2)
    st2 = sc.stats(cache2)
    assert st2["hits"] > st1["hits"]


def test_ges_jit_restricted_cached_trajectory_identical():
    from repro.core.partition import pid_table_from_allowed

    data, arities = _dataset(seed=9, n=8, m=160)
    n = arities.size
    rng = np.random.default_rng(0)
    allowed = np.zeros((n, n), bool)
    for y in range(n):
        cands = rng.choice([x for x in range(n) if x != y], 4, replace=False)
        allowed[cands, y] = True
    pt = jnp.asarray(np.asarray(pid_table_from_allowed(allowed)))
    init = np.zeros((n, n), np.int8)
    a0, s0, _, _ = ges_jit(data, arities, init, allowed,
                           config=GESConfig(max_q=64, counts_impl="fused",
                                            family_cache=False),
                           pid_table=pt)
    a1, s1, _, _, cache = ges_jit(
        data, arities, init, allowed,
        config=GESConfig(max_q=64, counts_impl="fused", family_cache=True,
                         cache_capacity=256),
        pid_table=pt, return_cache=True)
    assert np.array_equal(np.asarray(a0), np.asarray(a1))
    assert float(s0) == float(s1)
    assert sc.stats(cache)["misses"] > 0


@pytest.mark.parametrize("engine", ["host", "jax"])
def test_cges_cached_trajectory_identical(engine):
    data, arities = _dataset(seed=11, n=9, m=200)
    r0 = cges(data, arities, k=3, engine=engine,
              config=GESConfig(max_q=64, counts_impl="fused",
                               family_cache=False))
    r1 = cges(data, arities, k=3, engine=engine,
              config=GESConfig(max_q=64, counts_impl="fused",
                               family_cache=True, cache_capacity=2048))
    assert np.array_equal(r0.adj, r1.adj)
    assert r0.score == r1.score
    assert r0.rounds == r1.rounds
    assert r0.family_cache_stats is None
    st_ = r1.family_cache_stats
    assert st_ is not None and st_["hits"] > 0
    # ring members + rounds + fine-tune share families: real reuse
    assert st_["hit_rate"] > 0.2


def test_ring_cached_trajectory_subprocess():
    """Compiled shard_map ring, cache threaded through the round
    while_loop: trajectory identical to uncached, per-process hit stats
    returned, hit rate substantial (>= 0.3 at this tiny scale; the
    BENCH_sweep.json family_cache record pins >= 0.5 at bench scale)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, "src")
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import partition
        from repro.core.ges import GESConfig
        from repro.core.ring import RingSpec, ring_cges

        rng = np.random.default_rng(3)
        n, m, k = 10, 240, 2
        arities = rng.integers(2, 4, size=n).astype(np.int64)
        data = np.stack([rng.integers(0, a, size=m) for a in arities], 1)
        masks = partition.partition_edges(data, arities, k)
        mesh = Mesh(np.array(jax.devices())[:k], ("ring",))
        spec = RingSpec(k=k, max_rounds=8)

        g0, s0, r0 = ring_cges(data, arities, masks, mesh, spec,
                               GESConfig(max_q=64, counts_impl="fused",
                                         family_cache=False))
        cfg = GESConfig(max_q=64, counts_impl="fused", family_cache=True,
                        cache_capacity=1024)
        g1, s1, r1, stats = ring_cges(data, arities, masks, mesh, spec, cfg,
                                      return_cache_stats=True)
        assert np.array_equal(g0, g1)
        assert np.array_equal(s0, s1)
        assert r0 == r1
        assert len(stats) == k
        rates = [st["hit_rate"] for st in stats]
        assert all(st["hits"] > 0 for st in stats), stats
        assert max(rates) >= 0.3, stats
        # stats without the cache flag must fail loudly
        try:
            ring_cges(data, arities, masks, mesh, spec,
                      GESConfig(max_q=64, counts_impl="fused",
                                family_cache=False),
                      return_cache_stats=True)
        except ValueError:
            pass
        else:
            raise AssertionError("expected ValueError")
        print("RING_CACHE_OK", rates)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "RING_CACHE_OK" in r.stdout
