"""Integration: the multi-pod dry-run machinery end-to-end (subprocess —
the 512 forced host devices must never leak into this test process)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cell(arch, shape, mesh, tmp_path, extra=()):
    out = os.path.join(str(tmp_path), "cell.jsonl")
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--mesh", mesh,
           "--skip-extrap", "--out", out, *extra]
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run(cmd, capture_output=True, text=True, cwd=ROOT,
                       env=env, timeout=1200)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return json.loads(open(out).readlines()[-1])


@pytest.mark.slow
def test_dryrun_smallest_arch_single_pod(tmp_path):
    rec = _run_cell("whisper_base", "decode_32k", "pod1", tmp_path)
    assert rec["ok"] and rec["chips"] == 256
    assert rec["memory"]["argument_bytes"] > 0
    assert rec["seconds_compile"] > 0


@pytest.mark.slow
def test_dryrun_multi_pod_mesh(tmp_path):
    rec = _run_cell("mamba2_130m", "decode_32k", "pod2", tmp_path)
    assert rec["ok"] and rec["chips"] == 512


@pytest.mark.slow
def test_dryrun_records_skips(tmp_path):
    rec = _run_cell("gemma_7b", "long_500k", "pod1", tmp_path)
    assert rec["ok"] and rec.get("skipped")
    assert "full attention" in rec["reason"]


def test_device_count_not_leaked():
    """THIS process must see 1 CPU device (dry-run flags are subprocess-only)."""
    import jax
    assert len(jax.devices()) == 1
