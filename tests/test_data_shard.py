"""Data-axis sharding (core/sweeps ``data_shards`` / ring 2-D mesh):
sentinel-row padding neutrality per backend, psum'd sharded sweeps
table-identical to single-device entry-for-entry (d in {1, 2}, ragged
m % d != 0, all counts_impl backends; multi-device via subprocess), and
end-to-end trajectory identity for ges_host / ges_jit / the compiled ring.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import GESConfig, ges_host, pad_data_rows, sweeps
from repro.core.sweeps import sweep

from _hypothesis_compat import given, settings, st

IMPLS = ["segment", "onehot", "fused", "fused_pallas"]


def _case(seed=0, n=8, m=101):
    rng = np.random.default_rng(seed)
    arities = rng.integers(2, 4, size=n).astype(np.int64)
    data = np.stack([rng.integers(0, a, size=m) for a in arities], 1)
    return data.astype(np.int64), arities


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=97),
       st.integers(min_value=1, max_value=5))
def test_pad_data_rows_contract(m, d):
    """Padded rows: multiple-of-d length, original rows untouched, every
    sentinel cell == r_max (out of range for EVERY column's arity)."""
    rng = np.random.default_rng(m * 7 + d)
    n, r_max = 4, 3
    data = rng.integers(0, r_max, size=(m, n)).astype(np.int32)
    out = np.asarray(pad_data_rows(jnp.asarray(data), r_max, d))
    m_pad = ((m + d - 1) // d) * d
    assert out.shape == (m_pad, n)
    assert np.array_equal(out[:m], data)
    assert (out[m:] == r_max).all()


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("kind", ["insert", "delete"])
def test_sentinel_rows_are_neutral(impl, kind):
    """The padding trick itself, isolated from any mesh: a sweep over data
    with appended sentinel rows (value r_max in every column) is bitwise
    the unpadded sweep on EVERY backend — one_hot drops OOB rows, the
    segment paths route them to an explicit OOB bucket, and the Pallas
    kernels' select/slice can never match a value >= r_max."""
    data, arities = _case(seed=3)
    n = arities.size
    r_max = int(arities.max())
    adj = np.zeros((n, n), dtype=np.int8)
    adj[[1, 2], 0] = 1
    padded = np.asarray(pad_data_rows(jnp.asarray(data.astype(np.int32)),
                                      r_max, 4))
    assert padded.shape[0] > data.shape[0]      # 101 % 4 != 0: rows added
    aj = jnp.asarray(arities.astype(np.int32))
    kw = dict(kind=kind, y=0, ess=10.0, max_q=64, r_max=r_max,
              counts_impl=impl)
    ref = np.asarray(sweep(jnp.asarray(data.astype(np.int32)), aj,
                           jnp.asarray(adj), **kw))
    got = np.asarray(sweep(jnp.asarray(padded), aj, jnp.asarray(adj), **kw))
    np.testing.assert_array_equal(got, ref)


def test_data_shards_one_is_the_plain_path():
    """d=1 must not route through shard_map at all (no mesh required)."""
    data, arities = _case()
    n = arities.size
    adj = np.zeros((n, n), dtype=np.int8)
    dj = jnp.asarray(data.astype(np.int32))
    aj = jnp.asarray(arities.astype(np.int32))
    kw = dict(kind="insert", y=0, ess=10.0, max_q=64,
              r_max=int(arities.max()), counts_impl="segment")
    a = np.asarray(sweep(dj, aj, jnp.asarray(adj), **kw))
    b = np.asarray(sweep(dj, aj, jnp.asarray(adj), data_shards=1, **kw))
    np.testing.assert_array_equal(a, b)


def test_data_shards_validation():
    with pytest.raises(ValueError):
        GESConfig(data_shards=0)
    data, arities = _case()
    with pytest.raises(ValueError):
        sweep(jnp.asarray(data.astype(np.int32)),
              jnp.asarray(arities.astype(np.int32)),
              jnp.zeros((arities.size, arities.size), jnp.int8),
              kind="insert", y=0, ess=10.0, max_q=64,
              r_max=int(arities.max()), counts_impl="segment",
              data_shards=0)


def test_data_mesh_error_names_the_fix():
    """Asking for more data shards than devices must fail with the
    XLA_FLAGS hint, not an opaque mesh error (single-device test session)."""
    import jax

    want = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="host_platform_device_count"):
        sweeps._data_mesh(want)


def test_sharded_sweeps_table_identical_subprocess():
    """d in {2, 4}-device data meshes: column, matrix and restricted-matrix
    sweeps for both kinds on all three backend families are ENTRY-FOR-ENTRY
    identical to the single-device sweep, at ragged m (m % d != 0 exercises
    the sentinel padding through the real psum path)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, "src")
        import numpy as np
        import jax.numpy as jnp
        from repro.core.sweeps import sweep

        rng = np.random.default_rng(7)
        n, m = 8, 101
        arities = rng.integers(2, 4, size=n).astype(np.int64)
        data = np.stack([rng.integers(0, a, size=m) for a in arities], 1)
        dj = jnp.asarray(data.astype(np.int32))
        aj = jnp.asarray(arities.astype(np.int32))
        r_max = int(arities.max())
        adj = np.zeros((n, n), np.int8)
        adj[[1, 2], 0] = 1
        adj[[0, 3], 4] = 1
        adjj = jnp.asarray(adj)
        pids = jnp.asarray(np.array([1, 2, 3, 5], np.int32))
        tbl = jnp.asarray(
            np.stack([np.array([(y + i + 1) % n for i in range(3)],
                               np.int32) for y in range(n)]))
        kw = dict(ess=10.0, max_q=64, r_max=r_max)
        checked = 0
        for impl in ("segment", "onehot", "fused", "fused_pallas"):
            for kind in ("insert", "delete"):
                calls = [dict(kind=kind, y=0),
                         dict(kind=kind, y=0, pids=pids),
                         dict(kind=kind),
                         dict(kind=kind, pid_table=tbl)]
                for c in calls:
                    ref = np.asarray(sweep(dj, aj, adjj, counts_impl=impl,
                                           **kw, **c))
                    for d in (2, 4):
                        got = np.asarray(sweep(dj, aj, adjj,
                                               counts_impl=impl,
                                               data_shards=d, **kw, **c))
                        assert np.array_equal(got, ref), (impl, kind, d, c)
                        checked += 1
        assert checked == 64, checked
        print("SHARD_OK", checked)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "SHARD_OK" in r.stdout


def test_end_to_end_sharded_trajectories_subprocess():
    """ges_host (config.data_shards), ges_jit (the shard_map'd full-GES
    program) and the compiled ring on a 2-D (ring x data) mesh all take
    the IDENTICAL trajectory as their single-device runs (same adjacency,
    same score, same round count), with ragged m."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, "src")
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import GESConfig, ges_host, ges_jit, partition
        from repro.core.ring import RingSpec, ring_cges
        from repro.data.bn import forward_sample, random_bn

        rng = np.random.default_rng(11)
        bn = random_bn(rng, n=8, n_edges=9, max_parents=2)
        data = forward_sample(bn, 401, rng)     # ragged vs d=2
        n = bn.n

        # ges_host
        r1 = ges_host(data, bn.arities,
                      config=GESConfig(max_q=64, counts_impl="fused"))
        r2 = ges_host(data, bn.arities,
                      config=GESConfig(max_q=64, counts_impl="fused",
                                       data_shards=2))
        assert np.array_equal(r1.adj, r2.adj)
        assert r1.score == r2.score

        # ges_jit
        allowed = ~np.eye(n, dtype=bool)
        init = np.zeros((n, n), np.int8)
        a1, s1, _, _ = ges_jit(data, bn.arities, init, allowed,
                               config=GESConfig(max_q=64,
                                                counts_impl="segment"))
        a2, s2, _, _ = ges_jit(data, bn.arities, init, allowed,
                               config=GESConfig(max_q=64,
                                                counts_impl="segment",
                                                data_shards=2))
        assert np.array_equal(np.asarray(a1), np.asarray(a2))
        assert float(s1) == float(s2)

        # compiled ring: 1-D (ring,) vs 2-D (ring, data)
        k = 2
        masks = partition.partition_edges(data, bn.arities, k)
        devs = np.array(jax.devices())
        cfg = GESConfig(max_q=64, counts_impl="fused")
        g1, sc1, ro1 = ring_cges(
            data, bn.arities, masks, Mesh(devs[:k], ("ring",)),
            RingSpec(k=k, max_rounds=3), cfg)
        g2, sc2, ro2 = ring_cges(
            data, bn.arities, masks,
            Mesh(devs.reshape(k, 2), ("ring", "data")),
            RingSpec(k=k, max_rounds=3, data_axis="data",
                     data_axis_size=2), cfg)
        assert np.array_equal(g1, g2)
        assert np.array_equal(sc1, sc2)
        assert ro1 == ro2
        print("TRAJ_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "TRAJ_OK" in r.stdout
