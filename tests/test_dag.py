"""DAG utilities: closure, orders, moral graph, CPDAG."""
import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import dag


def _random_adj(seed, n=8, p=0.25):
    rng = np.random.default_rng(seed)
    return dag.random_dag_np(rng, n, int(p * n * (n - 1) / 2), max_parents=4)


def _closure_dfs(adj):
    n = adj.shape[0]
    reach = np.zeros_like(adj, dtype=bool)
    for s in range(n):
        stack = list(np.flatnonzero(adj[s]))
        seen = set()
        while stack:
            v = stack.pop()
            if v in seen:
                continue
            seen.add(v)
            reach[s, v] = True
            stack.extend(np.flatnonzero(adj[v]))
    return reach


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_transitive_closure_matches_dfs(seed):
    adj = _random_adj(seed)
    want = _closure_dfs(adj)
    assert np.array_equal(dag.transitive_closure_np(adj), want)
    assert np.array_equal(
        np.asarray(dag.transitive_closure(jnp.asarray(adj))), want)


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_incremental_closure(seed):
    adj = _random_adj(seed)
    reach = dag.transitive_closure_np(adj)
    rng = np.random.default_rng(seed + 5)
    # pick a non-edge that keeps the graph acyclic
    n = adj.shape[0]
    for _ in range(20):
        x, y = rng.integers(0, n, size=2)
        if x != y and not adj[x, y] and not reach[y, x]:
            break
    else:
        return
    adj2 = adj.copy()
    adj2[x, y] = True
    want = dag.transitive_closure_np(adj2)
    got = dag.closure_after_edge(reach, int(x), int(y))
    assert np.array_equal(got, want)


def test_is_dag():
    adj = np.zeros((3, 3), dtype=bool)
    adj[0, 1] = adj[1, 2] = True
    assert dag.is_dag_np(adj)
    adj[2, 0] = True
    assert not dag.is_dag_np(adj)
    assert not bool(dag.is_dag(jnp.asarray(adj)))


def test_topological_order():
    adj = np.zeros((4, 4), dtype=bool)
    adj[2, 0] = adj[0, 1] = adj[1, 3] = True
    order = dag.topological_order_np(adj)
    pos = {v: i for i, v in enumerate(order)}
    assert pos[2] < pos[0] < pos[1] < pos[3]


def test_moral_graph_marries_parents():
    # collider 0 -> 2 <- 1: moral graph must contain 0-1
    adj = np.zeros((3, 3), dtype=bool)
    adj[0, 2] = adj[1, 2] = True
    m = dag.moral_graph_np(adj)
    assert m[0, 1] and m[1, 0] and m[0, 2] and m[1, 2]


def test_smhd_zero_iff_same_moral():
    adj = np.zeros((3, 3), dtype=bool)
    adj[0, 1] = adj[1, 2] = True
    rev = adj.T.copy()          # chain reversed: same skeleton, no collider
    assert dag.smhd_np(adj, adj) == 0
    assert dag.smhd_np(adj, rev) == 0     # Markov equivalent chains
    collider = np.zeros((3, 3), dtype=bool)
    collider[0, 1] = collider[2, 1] = True
    assert dag.smhd_np(adj, collider) > 0


def test_cpdag_chain_vs_collider():
    # chain 0->1->2 is fully reversible; collider 0->1<-2 fully compelled
    chain = np.zeros((3, 3), dtype=bool)
    chain[0, 1] = chain[1, 2] = True
    c = dag.dag_to_cpdag_np(chain)
    assert c[0, 1] and c[1, 0] and c[1, 2] and c[2, 1]
    coll = np.zeros((3, 3), dtype=bool)
    coll[0, 1] = coll[2, 1] = True
    c = dag.dag_to_cpdag_np(coll)
    assert c[0, 1] and not c[1, 0] and c[2, 1] and not c[1, 2]


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_random_dag_is_dag(seed):
    adj = _random_adj(seed, n=12)
    assert dag.is_dag_np(adj)
    order = dag.topological_order_np(adj)
    assert len(order) == 12
