"""Serving path: prefill/decode consistency, greedy loop, cache shapes."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer
from repro.serving import build_prefill_step, build_serve_step, greedy_decode


def test_greedy_decode_runs_and_is_deterministic():
    cfg = get_smoke_config("qwen2_7b")
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    B, S = 2, 32
    cache = transformer.init_cache(cfg, B, S)
    tok0 = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    out1, _ = greedy_decode(cfg, params, cache, tok0, 0, 8)
    cache2 = transformer.init_cache(cfg, B, S)
    out2, _ = greedy_decode(cfg, params, cache2, tok0, 0, 8)
    assert out1.shape == (B, 8)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.vocab  # vocab padding never sampled


def test_prefill_step_matches_forward_last_token():
    cfg = dataclasses.replace(get_smoke_config("gemma_7b"),
                              param_dtype="float32", compute_dtype="float32")
    key = jax.random.PRNGKey(1)
    params = transformer.init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab)
    pre = build_prefill_step(cfg)(params, {"tokens": tokens})
    full, _ = transformer.forward(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_serve_step_advances_cache():
    cfg = get_smoke_config("zamba2_7b")
    key = jax.random.PRNGKey(2)
    params = transformer.init_params(key, cfg)
    B, S = 1, 16
    cache = transformer.init_cache(cfg, B, S)
    step = jax.jit(build_serve_step(cfg))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits1, cache = step(params, cache, tok, jnp.int32(0))
    logits2, cache = step(params, cache, tok, jnp.int32(1))
    assert logits1.shape == (B, cfg.vocab_pad)
    # SSM state must actually change between steps
    assert not np.allclose(np.asarray(logits1), np.asarray(logits2))


def test_whisper_decode_uses_cross_cache():
    cfg = dataclasses.replace(get_smoke_config("whisper_base"),
                              param_dtype="float32", compute_dtype="float32")
    key = jax.random.PRNGKey(3)
    params = transformer.init_params(key, cfg)
    B, S = 1, 8
    cache = transformer.init_cache(cfg, B, S)
    # fill cross-attention cache from a (stub) encoder output
    frames = jax.random.normal(key, (B, cfg.frontend_tokens,
                                     cfg.frontend_dim), jnp.float32)
    enc = transformer.encode(cfg, params, frames)
    from repro.models.layers import attention
    # precompute xk/xv rows per decoder layer (projection of enc output)
    import jax.numpy as jnp2
    xks, xvs = [], []
    for li in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[li], params["dec_layers"])
        k = jnp2.einsum("btd,dhk->bthk", enc, lp["xattn"]["wk"])
        v = jnp2.einsum("btd,dhk->bthk", enc, lp["xattn"]["wv"])
        xks.append(k)
        xvs.append(v)
    cache["xk"] = jnp2.stack(xks)
    cache["xv"] = jnp2.stack(xvs)
    tok = jnp2.zeros((B, 1), jnp2.int32)
    logits, cache2 = transformer.decode_step(cfg, params, cache, tok,
                                             jnp2.int32(0))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # zero cross cache must give different logits (cross-attn is live)
    cache["xk"] = jnp2.zeros_like(cache["xk"])
    cache["xv"] = jnp2.zeros_like(cache["xv"])
    logits0, _ = transformer.decode_step(cfg, params, cache, tok,
                                         jnp2.int32(0))
    assert not np.allclose(np.asarray(logits), np.asarray(logits0))
