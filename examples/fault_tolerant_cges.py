"""Fault tolerance for the paper's workload: kill a ring member mid-run and
let the elastic ring repair itself (the lost edge subset is re-merged into
the ring predecessor, preserving the disjoint cover of E).

    PYTHONPATH=src python examples/fault_tolerant_cges.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import GESConfig, ScoreCache, ges_host, partition
from repro.core.cges import edge_add_limit
from repro.core.dag import smhd_np
from repro.data.bn import forward_sample, random_bn
from repro.launch.cges_run import ring_rounds

rng = np.random.default_rng(2)
bn = random_bn(rng, n=16, n_edges=22, max_parents=3)
data = forward_sample(bn, 1500, rng)
config = GESConfig(max_q=512)
masks = partition.partition_edges(data, bn.arities, 4)
lim = edge_add_limit(bn.n, 4)

print("— run A: healthy 4-member ring —")
adj_a, score_a, rounds_a, _ = ring_rounds(
    data, bn.arities, masks, config, lim, max_rounds=10)

print("\n— run B: member 2 dies in round 1 (elastic repair to k=3) —")
adj_b, score_b, rounds_b, masks_b = ring_rounds(
    data, bn.arities, masks, config, lim, max_rounds=10,
    fail_at_round=1, fail_member=2)
assert masks_b.shape[0] == 3
off = ~np.eye(bn.n, dtype=bool)
assert np.all(masks_b.sum(axis=0)[off] == 1), "edge cover broken!"

cache = ScoreCache()
fin_a = ges_host(data, bn.arities, init_adj=adj_a, config=config, cache=cache)
fin_b = ges_host(data, bn.arities, init_adj=adj_b, config=config, cache=cache)
print(f"\nhealthy : BDeu/m={fin_a.score / len(data):.4f} "
      f"SMHD={smhd_np(fin_a.adj, bn.adj)}")
print(f"repaired: BDeu/m={fin_b.score / len(data):.4f} "
      f"SMHD={smhd_np(fin_b.adj, bn.adj)}")
print("the repaired ring still searches the full edge set E — "
      "same guarantees, one fewer worker")
