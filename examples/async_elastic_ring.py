"""The asynchronous elastic ring: concurrent members, overlapped transfer,
and a mid-run member death the survivors absorb.

Two demonstrations on one seeded problem:

1. HEALTHY: k members run concurrently (threads here; the multi-process
   form is ``python -m repro.launch.ring_async_run``), each posting its BN
   to its ring successor the moment its restricted sweep finishes.  The
   double-buffered mailbox makes neighbor transfer overlap compute, the
   circulating token replaces the per-round barrier — and the trajectory
   still matches the lockstep oracle exactly.
2. ELASTIC: the same run with one member going silent mid-run; its edge
   subset is folded into its ring predecessor (heartbeat detection +
   gossip) and the surviving k-1 members converge on a complete cover.

    PYTHONPATH=src python examples/async_elastic_ring.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import GESConfig, cges, partition
from repro.core.dag import is_dag_np
from repro.core.ring_async import run_ring_async_threads
from repro.data.bn import forward_sample, random_bn

K = 3
rng = np.random.default_rng(7)
bn = random_bn(rng, n=10, n_edges=12, max_parents=2)
data = forward_sample(bn, 600, rng)
config = GESConfig(max_q=256, counts_impl="fused")
masks = partition.partition_edges(data, bn.arities, K)

# ---- 1. healthy async run vs the lockstep oracle --------------------------
res_async = cges(data, bn.arities, k=K, limit=False, config=config,
                 engine="async", max_rounds=8, edge_masks=masks)
res_jax = cges(data, bn.arities, k=K, limit=False, config=config,
               engine="jax", max_rounds=8, edge_masks=masks)
print(f"async : score={res_async.score:.3f} rounds={res_async.rounds}")
print(f"oracle: score={res_jax.score:.3f} rounds={res_jax.rounds}")
assert res_async.rounds == res_jax.rounds
assert abs(res_async.score - res_jax.score) <= 1e-3
assert is_dag_np(res_async.adj)

# ---- 2. kill one member mid-run; the ring re-partitions -------------------
out = run_ring_async_threads(
    data, bn.arities, masks, config=config, max_rounds=8,
    die_member=1, die_after_round=1, hb_timeout_s=1.5, wall_limit_s=180.0)
assert out["survivors"] == [0, 2] and not out["timed_out"]
print(f"elastic: member 1 died after round 1; survivors {out['survivors']} "
      f"converged in {out['rounds']} rounds, best {out['best_score']:.3f}")
for i in out["survivors"]:
    for d in out["members"][i]["deaths"]:
        print(f"  member {i} learned of member {d['victim']}'s death "
              f"via {d['via']}")
print("OK")
