"""Quickstart: learn a Bayesian network with cGES and compare against GES/fGES.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import GESConfig, cges, fges_host, ges_host
from repro.core.bdeu import graph_score_np
from repro.core.dag import smhd_np
from repro.data.bn import forward_sample, random_bn

# 1. a ground-truth network + sampled data (paper: bnlearn nets, m=5000)
rng = np.random.default_rng(0)
bn = random_bn(rng, n=20, n_edges=26, max_parents=3)
data = forward_sample(bn, 3000, rng)
print(f"ground truth: n={bn.n}, edges={int(bn.adj.sum())}, "
      f"BDeu/m={graph_score_np(data, bn.arities, bn.adj) / len(data):.4f}")

config = GESConfig(max_q=512)

# 2. plain GES (the paper's control)
res_ges = ges_host(data, bn.arities, config=config)
print(f"GES   : BDeu/m={res_ges.score / len(data):9.4f} "
      f"SMHD={smhd_np(res_ges.adj, bn.adj):3d} evals={res_ges.n_score_evals}")

# 3. fGES baseline
res_fges = fges_host(data, bn.arities, config=config)
print(f"fGES  : BDeu/m={res_fges.score / len(data):9.4f} "
      f"SMHD={smhd_np(res_fges.adj, bn.adj):3d} evals={res_fges.n_score_evals}")

# 4. cGES-L (the paper's method): k=4 ring, edge-add limit (10/k)*sqrt(n)
res = cges(data, bn.arities, k=4, limit=True, config=config)
print(f"cGES-L: BDeu/m={res.score / len(data):9.4f} "
      f"SMHD={smhd_np(res.adj, bn.adj):3d} evals={res.n_score_evals} "
      f"rounds={res.rounds}")
print(f"ring trace (best BDeu per round): "
      f"{[round(s / len(data), 3) for s in res.ring_scores]}")
