"""The cGES ring as ONE compiled multi-device program (shard_map + ppermute).

Runs on 8 simulated host devices; on a TPU pod the same program runs on the
production mesh (see repro/launch/dryrun.py --arch cges_ring).

    PYTHONPATH=src python examples/distributed_ring.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, "src")

import numpy as np
import jax

from repro.core import GESConfig, fusion, ges_host, partition
from repro.core.cges import edge_add_limit
from repro.core.dag import is_dag_np, smhd_np
from repro.core.ring import RingSpec, ring_cges
from repro.data.bn import forward_sample, random_bn
from repro.launch.mesh import make_host_mesh

K = 4
rng = np.random.default_rng(1)
bn = random_bn(rng, n=14, n_edges=18, max_parents=3)
data = forward_sample(bn, 1200, rng)

config = GESConfig(max_q=256)
masks = partition.partition_edges(data, bn.arities, K)
mesh = make_host_mesh(K, axis="ring")
print(f"mesh: {mesh} (ring of {K} devices)")

# ring_cges derives per-process (n, W) pid_tables from the E_i masks, so
# every compiled round sweeps W = |E_i| candidates per column, not n.
pid_tables = partition.pid_tables(masks)
print(f"restricted sweep width: W={pid_tables.shape[2]} vs n={bn.n}")
graphs, scores, rounds = ring_cges(
    data, bn.arities, masks, mesh, RingSpec(k=K, max_rounds=8), config,
    add_limit=edge_add_limit(bn.n, K), pid_tables=pid_tables)
best = int(np.argmax(scores))
print(f"ring converged in {rounds} rounds; "
      f"per-process BDeu: {[round(float(s), 1) for s in scores]}")

# The merge the compiled ring traced each round is the SAME unified layer
# (core/fusion.py) callable from the host: fuse the k per-process winners
# into one sigma-consistent edge union — host and jit engines agree
# adjacency-for-adjacency.
consensus = fusion.fuse(list(graphs), engine="host")
assert np.array_equal(consensus, fusion.fuse(list(graphs), engine="jit"))
print(f"edge union of the {K} process BNs: {int(consensus.sum())} edges "
      f"(host == jit engine)")

# fine-tuning pass (host GES, unrestricted) — preserves GES guarantees
res = ges_host(data, bn.arities, init_adj=graphs[best], config=config)
assert is_dag_np(res.adj)
print(f"after fine-tune: BDeu/m={res.score / len(data):.4f} "
      f"SMHD vs truth={smhd_np(res.adj, bn.adj)}")
