"""End-to-end LM training driver (reduced mamba2 config) with checkpoint
restart — the framework's (b) 'train a model for a few hundred steps' example.

    PYTHONPATH=src python examples/train_lm.py
"""
import subprocess
import sys
import tempfile

tmp = tempfile.mkdtemp(prefix="repro_ckpt_")
base = [sys.executable, "-m", "repro.launch.train",
        "--arch", "mamba2_130m", "--smoke", "--batch", "8", "--seq", "128",
        "--ckpt-dir", tmp, "--ckpt-every", "100", "--log-every", "50"]

# phase 1: 200 steps
print(">>> training 200 steps")
subprocess.run(base + ["--steps", "200"], check=True,
               env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})

# phase 2: simulate a restart — resume from the step-200 checkpoint and
# continue to 300 (identical batches are replayed deterministically)
print(">>> resuming to 300 steps (fault-tolerant restart)")
subprocess.run(base + ["--steps", "300", "--resume"], check=True,
               env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
print("done — loss continued decreasing across the restart")
