"""Batched serving example: slot-pool continuous batching over the decode
step (the production shape of `decode_32k`, reduced config on CPU).

    PYTHONPATH=src python examples/serve_requests.py
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve",
     "--arch", "qwen2_7b", "--smoke", "--slots", "4",
     "--max-new", "12", "--requests", "6"],
    check=True,
    env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
